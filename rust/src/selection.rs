//! Selection strategies: GRAD-MATCH and every baseline the paper compares
//! against (§5), behind one [`Strategy`] trait the trainer drives every `R`
//! epochs (Algorithm 1).
//!
//! | spec string            | algorithm                                            |
//! |------------------------|------------------------------------------------------|
//! | `gradmatch`            | OMP, per-class + per-gradient approx (paper default) |
//! | `gradmatch-perclass`   | OMP per class on full-P gradients (Table 11)         |
//! | `gradmatch-pb`         | OMP over per-mini-batch gradients                    |
//! | `craig` / `craig-pb`   | facility location over gradient distances            |
//! | `glister`              | Taylor-approximation greedy on val-gradient dots     |
//! | `random`               | uniform subset                                       |
//! | `full`                 | entire ground set (skyline / early-stop baseline)    |
//! | `entropy`              | max predictive entropy (Table 12)                    |
//! | `forgetting`           | forgetting-events counter (Table 12)                 |
//! | `featurefl`            | facility location on raw features (Table 12)         |
//!
//! A trailing `-warm` on any spec enables the κ warm-start schedule, which
//! the trainer owns (`T_f = κ·T·k/n` full epochs first — §4 of the paper).
//!
//! Since the engine redesign ([`crate::engine`]) strategies are thin
//! stateful shells over **stateless solvers** ([`solve_classes_omp`],
//! [`solve_classes_fl`], [`glister_rank`], [`staged_targets`]) that
//! consume staged gradient views.  Rounds driven by a
//! [`crate::engine::SelectionEngine`] stage through the engine's shared
//! cache (`SelectCtx::round`), so N strategies against one model state
//! pay ONE staging pass; the legacy `parse_strategy` + `select` path
//! stages privately and behaves exactly as before.
//!
//! # The parallel selection-round engine
//!
//! Per-class strategies (GRAD-MATCH per-class variants, CRAIG's per-class
//! arm, GLISTER, FeatureFL) run as a two-stage round:
//!
//! 1. **Stage** — one padded runtime pass over the full ground set
//!    ([`grads::stage_class_grads`]) scatters each sample's gradient
//!    slice into its class's matrix and yields the per-class train-side
//!    targets for free (`⌈|ground|/chunk⌉` dispatches, vs the old
//!    `Σ_c ⌈n_c/chunk⌉` gradient passes *plus* `Σ_c ⌈n_c/chunk⌉` target
//!    passes).  Validation targets (`is_valid`) keep the fused
//!    `[P]`-readback means per populated val class (readback, not
//!    dispatch count, dominates that term on device backends); GLISTER,
//!    which only needs scalar Taylor gains, streams through
//!    [`grads::score_grads`] without materializing the store at all.
//! 2. **Fan out** — the per-class solves are independent pure-CPU
//!    problems, so they run concurrently on [`crate::par::map_tasks`]
//!    (class-level work stealing; inner kernels degrade to serial via the
//!    depth guard) and merge deterministically in class order.  Fan-out
//!    engages per [`crate::par::fanout_wins`]: with fewer live classes
//!    than cores and solves big enough to thread internally, the serial
//!    loop keeps kernel-level parallelism instead — class fan-out
//!    replaces kernel threading, so it must only run where it wins.
//!
//! Cost model per round (C classes, n ground rows, k budget): staging is
//! `⌈n/chunk⌉` fixed-shape dispatches + O(n·P) scatter; the solve stage
//! is `Σ_c OMP(n_c, k_c)` spread across the machine, wall-clock
//! ≈ `max_c OMP(n_c, k_c)` when C ≥ cores.  The pre-engine serial path is
//! preserved on [`GradMatch`] (`parallel = false`) as the pinned
//! equivalence baseline: same supports and weights within 1e-4,
//! bit-identical merge order (see `tests/round_engine.rs` and the
//! `micro_hotpath` selection-round bench).

use std::cmp::Ordering;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::Dataset;
use crate::engine::{RoundShared, ShardPlan, SketchPlan};
use crate::grads::{self, ClassStage, EvalEntries, GradOracle, GradientStore, RtGrads, StageWidth};
use crate::omp::{omp_select, omp_select_rust, OmpOpts, OmpResult, XlaCorr};
use crate::par;
use crate::rng::Rng;
use crate::runtime::{ModelState, Runtime};
use crate::sketch::{SketchSolve, Sketcher};
use crate::submod::{lazy_greedy, FacilityLocation};
use crate::tensor::Matrix;

/// The gradient source behind a selection round: the live PJRT runtime +
/// model snapshot, or an explicit [`GradOracle`] (device-free — every
/// spec in [`strategy_specs`] runs over either, with the XLA solve arms
/// falling back to the Rust solvers when no runtime is present).
pub enum GradSource<'a> {
    Live {
        rt: &'a Runtime,
        state: &'a ModelState,
    },
    Oracle {
        oracle: &'a mut dyn GradOracle,
        /// hidden width H of the class column layout (`P = H*C + C`)
        h: usize,
        /// class count C
        c: usize,
    },
}

/// Run `f` against the source's oracle view — [`RtGrads`] constructed on
/// the fly for live rounds, the caller's oracle otherwise.  Every
/// acquisition pass a strategy issues funnels through here, which is
/// where engine-driven rounds pick up their fault tolerance: the oracle
/// is wrapped in the round's [`grads::RetryPolicy`] so transient chunk
/// dispatch failures are retried instead of aborting the round, with
/// observed retries folded into the round probe
/// (`RoundStats::retries`).  Legacy rounds (`round = None`) dispatch
/// bare — bit-identical pre-engine behavior.
fn with_oracle<R>(
    src: &mut GradSource<'_>,
    round: Option<&RoundShared>,
    f: impl FnOnce(&mut dyn GradOracle) -> R,
) -> R {
    match src {
        GradSource::Live { rt, state } => {
            retrying_in(&mut RtGrads { rt: *rt, st: *state }, round, f)
        }
        GradSource::Oracle { oracle, .. } => retrying_in(&mut **oracle, round, f),
    }
}

fn retrying_in<R>(
    oracle: &mut dyn GradOracle,
    round: Option<&RoundShared>,
    f: impl FnOnce(&mut dyn GradOracle) -> R,
) -> R {
    match round {
        Some(shared) => {
            let mut retrying = grads::Retrying::new(oracle, shared.retry_policy());
            let out = f(&mut retrying);
            shared.note_retries(retrying.retries);
            out
        }
        None => f(oracle),
    }
}

/// Everything a strategy may look at when selecting.  Since the engine
/// redesign this is a thin borrow of the round: gradients and eval
/// streams come through the [`GradSource`] oracle seam, and the staged
/// store lives in the engine's [`RoundShared`] cache (when the round is
/// engine-driven), consumed through [`SelectCtx::class_stages`].
pub struct SelectCtx<'a> {
    pub src: GradSource<'a>,
    pub train: &'a Dataset,
    /// ground set: dataset rows eligible for selection (handles imbalance)
    pub ground: &'a [usize],
    pub val: &'a Dataset,
    /// subset size k (samples)
    pub budget: usize,
    /// OMP ridge λ
    pub lambda: f32,
    /// OMP tolerance ε
    pub eps: f32,
    /// match validation gradients instead of training gradients (L = L_V)
    pub is_valid: bool,
    pub rng: &'a mut Rng,
    /// Round-scoped engine state: the staged-gradient cache every
    /// request of the round shares, plus the observability probe.
    /// `None` on the legacy [`parse_strategy`] + [`Strategy::select`]
    /// path — strategies then stage privately, exactly the pre-engine
    /// behavior.
    pub round: Option<&'a RoundShared>,
}

impl<'a> SelectCtx<'a> {
    /// The live runtime + snapshot when this round has one — the gate the
    /// XLA solve arms check before touching device kernels (oracle-backed
    /// rounds fall back to the Rust solvers).
    pub fn live(&self) -> Option<(&'a Runtime, &'a ModelState)> {
        match &self.src {
            GradSource::Live { rt, state } => Some((*rt, *state)),
            GradSource::Oracle { .. } => None,
        }
    }

    /// `(H, C)` of the class column layout (`P = H*C + C`).
    pub fn class_layout(&self) -> (usize, usize) {
        match &self.src {
            GradSource::Live { state, .. } => (state.meta.h, state.meta.c),
            GradSource::Oracle { h, c, .. } => (*h, *c),
        }
    }

    /// Staged per-class gradients for this round — served from the
    /// engine's shared cache when present (N requests, one
    /// [`grads::stage_class_grads`] pass), else staged privately.  The
    /// cache always carries targets; `want_targets` only trims the
    /// private path's host-side accumulation.
    pub fn class_stages(
        &mut self,
        width: StageWidth,
        want_targets: bool,
    ) -> Result<Arc<Vec<ClassStage>>> {
        let (h, c) = self.class_layout();
        let (round, train, ground) = (self.round, self.train, self.ground);
        with_oracle(&mut self.src, round, |oracle| match round {
            Some(shared) => shared.class_stages(oracle, train, ground, h, c, width),
            None => Ok(Arc::new(grads::stage_class_grads_with(
                oracle,
                train,
                ground,
                h,
                c,
                width,
                want_targets,
            )?)),
        })
    }

    /// Validation-side class mean gradients for the round's live classes
    /// — cached in the engine's [`RoundShared`] when present (an
    /// `is_valid` sweep pays the per-class `[P]` readbacks once, not
    /// once per request), else computed directly.
    pub fn val_class_means(&mut self, flags: &[bool]) -> Result<Arc<Vec<Option<Vec<f32>>>>> {
        let (_, c) = self.class_layout();
        let (round, val) = (self.round, self.val);
        with_oracle(&mut self.src, round, |oracle| match round {
            Some(shared) => shared.val_class_means(oracle, val, c, flags),
            None => Ok(Arc::new(grads::live_val_class_means_with(oracle, val, c, flags)?)),
        })
    }

    /// Mean gradient over `rows` of the train (or, when `on_val`, the
    /// validation) split — the matching target ∇L(θ).
    pub fn mean_gradient(&mut self, on_val: bool, rows: &[usize]) -> Result<Vec<f32>> {
        let ds = if on_val { self.val } else { self.train };
        with_oracle(&mut self.src, self.round, |oracle| grads::mean_gradient_with(oracle, ds, rows))
    }

    /// Per-sample gradients for `rows` of the train split (the serial
    /// reference path; staged rounds go through [`SelectCtx::class_stages`]).
    pub fn per_sample_grads(&mut self, rows: &[usize]) -> Result<GradientStore> {
        let train = self.train;
        with_oracle(&mut self.src, self.round, |oracle| {
            grads::per_sample_grads_with(oracle, train, rows)
        })
    }

    /// Streamed Taylor gains `g_i · v` over the ground set (GLISTER).
    pub fn score_grads(&mut self, v: &[f32]) -> Result<Vec<f32>> {
        let (train, ground) = (self.train, self.ground);
        with_oracle(&mut self.src, self.round, |oracle| {
            grads::score_grads_with(oracle, train, ground, v)
        })
    }

    /// Per-mini-batch mean gradients over `order` via the source's fused
    /// group reduction (the PB ground sets).
    pub fn per_batch_grads(&mut self, order: &[usize]) -> Result<(Matrix, Vec<Vec<usize>>)> {
        let train = self.train;
        with_oracle(&mut self.src, self.round, |oracle| {
            grads::per_batch_grads_fused_with(oracle, train, order)
        })
    }

    /// Per-sample eval entries over `indices` of the train split, one
    /// padded pass (ENTROPY, FORGETTING).
    pub fn eval_entries(&mut self, indices: &[usize]) -> Result<EvalEntries> {
        let train = self.train;
        with_oracle(&mut self.src, self.round, |oracle| {
            grads::eval_entries_with(oracle, train, indices)
        })
    }

    /// Record per-round observability (per-class budgets, the
    /// fan-out-vs-serial decision) into the engine probe; no-op on the
    /// legacy path.
    pub fn note_round(&self, budgets: &[usize], fanout: bool) {
        if let Some(shared) = self.round {
            shared.note_budgets(budgets);
            shared.note_fanout(fanout);
        }
    }

    /// The round's sharding plan, when the request carried one.  Legacy
    /// rounds (`round = None`) never shard.
    pub fn shard_plan(&self) -> Option<ShardPlan> {
        self.round.and_then(|r| r.shard_plan())
    }

    /// Record sharded-round observability (shard count, merge-pool size,
    /// peak simultaneously staged rows); no-op on the legacy path.
    pub fn note_shards(&self, shards: usize, merge_candidates: usize, peak_staged_rows: usize) {
        if let Some(shared) = self.round {
            shared.note_shards(shards, merge_candidates, peak_staged_rows);
        }
    }

    /// The round's sketch plan, when the request carried one.  Legacy
    /// rounds (`round = None`) never sketch.
    pub fn sketch_plan(&self) -> Option<SketchPlan> {
        self.round.and_then(|r| r.sketch_plan())
    }

    /// Record sketched-solve observability (applied width, projection and
    /// re-fit seconds); no-op on the legacy path.
    pub fn note_sketch(&self, width: usize, sketch_secs: f64, refit_secs: f64) {
        if let Some(shared) = self.round {
            shared.note_sketch(width, sketch_secs, refit_secs);
        }
    }

    /// Stage one shard slice of the ground set through the oracle seam —
    /// same retry wrapping and quarantine as the flat staging pass, but
    /// the result is NOT inserted into the round's shared cache: shard
    /// stages are transient, and `prev` lets the caller recycle the
    /// previous slot's buffers ([`grads::stage_shard_grads`]).  Staging
    /// time and chunk dispatches are folded into the round probe's
    /// shard-stage counters.
    pub fn stage_shard(
        &mut self,
        shard_ground: &[usize],
        width: StageWidth,
        prev: Vec<ClassStage>,
    ) -> Result<Vec<ClassStage>> {
        let (h, c) = self.class_layout();
        let (round, train) = (self.round, self.train);
        let t0 = Instant::now();
        let staged = with_oracle(&mut self.src, round, |oracle| {
            let chunk = oracle.chunk_rows().max(1);
            grads::stage_shard_grads(oracle, train, shard_ground, h, c, width, true, prev)
                .map(|(stages, reused, quarantined)| (stages, reused, quarantined, chunk))
        });
        let (stages, reused, quarantined, chunk) = staged?;
        if let Some(shared) = round {
            shared.note_shard_stage(
                t0.elapsed().as_secs_f64(),
                shard_ground.len().div_ceil(chunk),
                quarantined,
                reused,
            );
        }
        Ok(stages)
    }
}

/// A selected weighted subset.  `indices` are dataset rows; `weights`
/// align 1:1 (non-negative; the weighted loss normalizes, so scale is
/// irrelevant).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Selection {
    pub indices: Vec<usize>,
    pub weights: Vec<f32>,
    /// gradient-matching residual where the strategy computes one
    pub grad_error: Option<f32>,
}

impl Selection {
    fn push(&mut self, idx: usize, w: f32) {
        self.indices.push(idx);
        self.weights.push(w);
    }
}

/// A data-selection strategy (Algorithm 1's OMP slot, or a baseline).
pub trait Strategy {
    fn name(&self) -> String;
    /// Whether re-selection every R epochs is useful (adaptive strategies).
    fn is_adaptive(&self) -> bool {
        true
    }
    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection>;
}

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

/// Ground-set rows per class.
fn ground_per_class(ds: &Dataset, ground: &[usize]) -> Vec<Vec<usize>> {
    let mut per = vec![Vec::new(); ds.classes];
    for &i in ground {
        per[ds.y[i] as usize].push(i);
    }
    per
}

/// Split budget k across classes proportionally to class sizes (largest
/// remainder; every non-empty class gets ≥ 1 when k ≥ #classes).
pub fn split_budget(k: usize, sizes: &[usize]) -> Vec<usize> {
    let total: usize = sizes.iter().sum();
    if total == 0 {
        return vec![0; sizes.len()];
    }
    let mut out = vec![0usize; sizes.len()];
    let mut rems: Vec<(f64, usize)> = Vec::new();
    let mut assigned = 0usize;
    for (c, &s) in sizes.iter().enumerate() {
        let exact = k as f64 * s as f64 / total as f64;
        let base = (exact.floor() as usize).min(s);
        out[c] = base;
        assigned += base;
        rems.push((exact - base as f64, c));
    }
    rems.sort_by(|a, b| b.0.total_cmp(&a.0));
    // Hand out the remainder in largest-remainder order until it is gone
    // or every class is saturated.  (A bounded `cycle().take(2·len)` pass
    // could strand budget when only a few classes still had spare
    // capacity; the progress guard makes exhaustion explicit.)
    let mut left = k.saturating_sub(assigned);
    while left > 0 {
        let mut progressed = false;
        for &(_, c) in &rems {
            if left == 0 {
                break;
            }
            if out[c] < sizes[c] {
                out[c] += 1;
                left -= 1;
                progressed = true;
            }
        }
        if !progressed {
            break; // every class saturated — k exceeds the ground set
        }
    }
    out
}

/// NaN-safe descending order on scores, matching [`crate::tensor::argmax`]
/// semantics: higher scores first, and a NaN score never outranks a real
/// one (NaNs order after every number, equal among themselves).
fn rank_desc(a: f32, b: f32) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => b.partial_cmp(&a).expect("both scores are non-NaN"),
    }
}

/// Indices of the `k` largest scores in descending rank order — NaN-safe
/// (NaN never wins; ties keep the smaller index) and partial:
/// `select_nth_unstable` partitions in O(n), then only the top-k slice is
/// sorted (O(n + k log k) vs the old full O(n log n) sort, which also
/// panicked on any NaN score).
pub fn top_k_desc(scores: &[f32], k: usize) -> Vec<usize> {
    let n = scores.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    let cmp = |a: &usize, b: &usize| rank_desc(scores[*a], scores[*b]).then(a.cmp(b));
    if k < n {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_unstable_by(cmp);
    idx
}

/// Classes worth solving — positive size and budget — in class order
/// (the deterministic merge order of the round engine).
fn live_by_sizes(sizes: &[usize], budgets: &[usize]) -> Vec<usize> {
    (0..sizes.len()).filter(|&cls| sizes[cls] > 0 && budgets[cls] > 0).collect()
}

/// [`live_by_sizes`] over staged gradients.
fn live_classes(stages: &[ClassStage], budgets: &[usize]) -> Vec<usize> {
    let sizes: Vec<usize> = stages.iter().map(|s| s.rows.len()).collect();
    live_by_sizes(&sizes, budgets)
}

/// Per-class liveness flags sized to `c` (the shape
/// [`grads::live_val_class_means_with`] consumes).
pub fn live_flags(stages: &[ClassStage], budgets: &[usize], c: usize) -> Vec<bool> {
    let mut flags = vec![false; c];
    for &cls in &live_classes(stages, budgets) {
        flags[cls] = true;
    }
    flags
}

/// Dominant inner-kernel cost of the live OMP solves — the O(n_c·w)
/// correlation GEMV of the largest class.
fn omp_max_work(stages: &[ClassStage], live: &[usize]) -> usize {
    live.iter().map(|&cls| stages[cls].g.rows * stages[cls].g.cols).max().unwrap_or(0)
}

/// The round's fan-out-vs-serial decision for a set of staged OMP
/// problems — the exact predicate [`solve_classes_omp`] applies, exposed
/// so the engine report and the execution cannot drift.
pub fn omp_fanout_wins(stages: &[ClassStage], budgets: &[usize]) -> bool {
    let live = live_classes(stages, budgets);
    par::fanout_wins(live.len(), omp_max_work(stages, &live))
}

/// Run `solve` once per live class — fanned out across the machine
/// ([`par::map_tasks`], class-level work stealing) when `fanout`, else a
/// serial loop; results come back in class order either way.  Callers
/// decide `fanout` via [`par::fanout_wins`] over the dominant
/// inner-kernel cost of their solves.  The one scaffold every per-class
/// strategy arm shares.
fn solve_per_class<T: Send>(
    live: &[usize],
    fanout: bool,
    solve: impl Fn(&usize) -> T + Sync,
) -> Vec<T> {
    if fanout {
        par::map_tasks(live, solve)
    } else {
        live.iter().map(solve).collect()
    }
}

/// The one merge contract of the round engine: walk per-class OMP results
/// **in class order**, calibrate weights to the class *sum* (×n_c — OMP
/// fits the class *mean* gradient; the scaling keeps weights comparable
/// with CRAIG's medoid counts and the paper's Err(w, X) accounting), and
/// average the residual norms into `grad_error`.  Every solve arm (CPU
/// serial, CPU fan-out, XLA) funnels through this.
fn merge_class_omp(stages: &[ClassStage], picks: Vec<(usize, OmpResult)>) -> Selection {
    merge_class_omp_scaled(stages, picks, None)
}

/// [`merge_class_omp`] with an explicit per-class weight scale.  The
/// sharded merge round solves over a *reduced* candidate pool whose
/// per-class row counts are not the class sizes — its weights must still
/// calibrate to the FULL ground class sizes to stay comparable with the
/// flat path, so it passes those in via `scales`.
fn merge_class_omp_scaled(
    stages: &[ClassStage],
    picks: Vec<(usize, OmpResult)>,
    scales: Option<&[f32]>,
) -> Selection {
    let mut out = Selection::default();
    let mut err_acc = 0.0f64;
    let mut err_n = 0usize;
    for (cls, res) in picks {
        let scale = match scales {
            Some(sc) => sc[cls],
            None => stages[cls].rows.len() as f32,
        };
        for (slot, &j) in res.selected.iter().enumerate() {
            out.push(stages[cls].rows[j], res.weights[slot] * scale);
        }
        err_acc += res.residual_norm as f64;
        err_n += 1;
    }
    if err_n > 0 {
        out.grad_error = Some((err_acc / err_n as f64) as f32);
    }
    out
}

/// Solve every class's OMP problem over staged gradients and merge the
/// per-class selections through [`merge_class_omp`] (bit-identical merge
/// whether the solves ran serially or fanned out).  `targets[c]` must
/// already be sliced to `stages[c].g`'s width.  Pure CPU — no runtime
/// access — which is what makes the class fan-out safe and the engine
/// testable without a device.  Fan-out engages only when it beats
/// kernel-level threading ([`par::fanout_wins`]): with fewer live
/// classes than cores *and* per-class solves big enough to thread
/// internally, the serial loop keeps the inner GEMVs parallel instead.
pub fn solve_classes_omp(
    stages: &[ClassStage],
    budgets: &[usize],
    targets: &[Vec<f32>],
    lambda: f32,
    eps: f32,
    parallel: bool,
) -> Result<Selection> {
    solve_classes_omp_scaled(stages, budgets, targets, lambda, eps, parallel, None)
}

/// [`solve_classes_omp`] with an explicit per-class weight scale for the
/// merge ([`merge_class_omp_scaled`]) — the sharded merge round's final
/// solve over the winner pool.
pub fn solve_classes_omp_scaled(
    stages: &[ClassStage],
    budgets: &[usize],
    targets: &[Vec<f32>],
    lambda: f32,
    eps: f32,
    parallel: bool,
    scales: Option<&[f32]>,
) -> Result<Selection> {
    assert_eq!(stages.len(), budgets.len(), "one budget per class");
    assert_eq!(stages.len(), targets.len(), "one target per class");
    let live = live_classes(stages, budgets);
    let solve = |cls: &usize| -> Result<OmpResult> {
        let cls = *cls;
        let opts = OmpOpts { k: budgets[cls], lambda, eps };
        omp_select_rust(&stages[cls].g, &targets[cls], opts)
    };
    let fan = parallel && par::fanout_wins(live.len(), omp_max_work(stages, &live));
    let results: Vec<Result<OmpResult>> = solve_per_class(&live, fan, solve);
    let mut picks = Vec::with_capacity(live.len());
    for (&cls, res) in live.iter().zip(results) {
        picks.push((cls, res?));
    }
    Ok(merge_class_omp_scaled(stages, picks, scales))
}

/// One shard's staged Batch-OMP problem: the shard slice's per-class
/// stages, per-class nomination budgets, and matching targets (already
/// sliced to the stage width).
pub struct ShardOmp {
    pub stages: Vec<ClassStage>,
    pub budgets: Vec<usize>,
    pub targets: Vec<Vec<f32>>,
}

/// First level of the two-level hierarchical OMP: solve every staged
/// shard's per-class OMP problem independently, fanned out across the
/// machine via [`par::map_tasks`] when more than one shard is staged.
/// The nested per-class fan-out inside each shard solve degrades to
/// serial through the par depth guard, so shard-level and class-level
/// parallelism never oversubscribe.  Shard selections come back in
/// shard order — the merge round's determinism depends on it.
pub fn solve_shards_omp(
    problems: &[ShardOmp],
    lambda: f32,
    eps: f32,
    parallel: bool,
) -> Result<Vec<Selection>> {
    let solve = |p: &ShardOmp| -> Result<Selection> {
        solve_classes_omp(&p.stages, &p.budgets, &p.targets, lambda, eps, true)
    };
    let results: Vec<Result<Selection>> = if parallel && problems.len() > 1 {
        par::map_tasks(problems, solve)
    } else {
        problems.iter().map(solve).collect()
    };
    results.into_iter().collect()
}

/// The class fan-out decision for *sketched* solves: same predicate as
/// [`omp_fanout_wins`], but over the sketched inner-kernel cost `n_c·k`
/// instead of the full staged width.  Exposed so the round probe records
/// the exact decision [`solve_classes_omp_sketched`] applies.
pub fn sketched_fanout_wins(stages: &[ClassStage], budgets: &[usize], k: usize) -> bool {
    let live = live_classes(stages, budgets);
    let max_work = live.iter().map(|&cls| stages[cls].g.rows * k).max().unwrap_or(0);
    par::fanout_wins(live.len(), max_work)
}

/// Per-class global-column maps for the sketcher: class-sliced stages map
/// local column `j` to `grads::class_columns(h, c, cls)[j]`, full-width
/// stages to `j` itself — so every staging path (flat, sharded, merge)
/// derives the identical projection row for the same gradient dimension.
pub fn sketch_col_maps(h: usize, c: usize, per_gradient: bool, p: usize) -> Vec<Vec<usize>> {
    (0..c)
        .map(|cls| {
            if per_gradient {
                grads::class_columns(h, c, cls)
            } else {
                (0..p).collect()
            }
        })
        .collect()
}

/// Derive the round's sketcher from the request RNG and the plan's salt.
/// `Rng::split` is non-mutating, so rounds whose plan is absent or
/// inapplicable (`k ≥ P`) leave the stream untouched — the flat
/// fall-through stays bit-identical.
pub fn sketcher_for(rng: &Rng, plan: &SketchPlan) -> Sketcher {
    const SKETCH_SEED_TAG: u64 = 0x4A4C_5348; // "JLSH"
    let mut s = rng.split(SKETCH_SEED_TAG);
    Sketcher::new(plan.width, s.next_u64(), plan.seed_salt)
}

/// Sketched twin of [`solve_classes_omp_scaled`]: each live class's
/// Batch-OMP runs against a seeded JL projection of its staged gradients
/// (`[n_c, w] → [n_c, k]`, `k < w`), with weights optionally re-fit at
/// the full staged width on the selected support
/// ([`crate::sketch::solve_sketched_omp`]).  Identical merge contract
/// ([`merge_class_omp_scaled`]).  Returns the selection plus the
/// aggregate projection / re-fit seconds (summed across class tasks).
#[allow(clippy::too_many_arguments)]
pub fn solve_classes_omp_sketched(
    stages: &[ClassStage],
    budgets: &[usize],
    targets: &[Vec<f32>],
    lambda: f32,
    eps: f32,
    parallel: bool,
    scales: Option<&[f32]>,
    sketcher: &Sketcher,
    col_maps: &[Vec<usize>],
    refit: bool,
) -> Result<(Selection, f64, f64)> {
    assert_eq!(stages.len(), budgets.len(), "one budget per class");
    assert_eq!(stages.len(), targets.len(), "one target per class");
    assert_eq!(stages.len(), col_maps.len(), "one column map per class");
    let live = live_classes(stages, budgets);
    let solve = |cls: &usize| -> Result<SketchSolve> {
        let cls = *cls;
        let opts = OmpOpts { k: budgets[cls], lambda, eps };
        crate::sketch::solve_sketched_omp(
            sketcher,
            &stages[cls].g,
            &col_maps[cls],
            &targets[cls],
            opts,
            refit,
        )
    };
    let fan = parallel && sketched_fanout_wins(stages, budgets, sketcher.width());
    let results: Vec<Result<SketchSolve>> = solve_per_class(&live, fan, solve);
    let mut picks = Vec::with_capacity(live.len());
    let (mut sk_secs, mut rf_secs) = (0.0f64, 0.0f64);
    for (&cls, res) in live.iter().zip(results) {
        let s = res?;
        sk_secs += s.sketch_secs;
        rf_secs += s.refit_secs;
        picks.push((
            cls,
            OmpResult {
                selected: s.selected,
                weights: s.weights,
                residual_norm: s.residual_norm,
                iters: s.iters,
            },
        ));
    }
    Ok((merge_class_omp_scaled(stages, picks, scales), sk_secs, rf_secs))
}

/// Sketched twin of [`solve_shards_omp`] — the first level of the
/// two-level hierarchical OMP with every shard solve running in sketch
/// space.  Shard solves only *nominate* candidates (their weights are
/// discarded by the merge round), so the full-width re-fit is skipped
/// here: the merge round's full-width solve over the winner pool IS the
/// composition's re-fit.  Returns the shard selections (shard order) plus
/// the aggregate projection seconds.
pub fn solve_shards_omp_sketched(
    problems: &[ShardOmp],
    lambda: f32,
    eps: f32,
    parallel: bool,
    sketcher: &Sketcher,
    col_maps: &[Vec<usize>],
) -> Result<(Vec<Selection>, f64)> {
    let solve = |p: &ShardOmp| -> Result<(Selection, f64, f64)> {
        solve_classes_omp_sketched(
            &p.stages, &p.budgets, &p.targets, lambda, eps, true, None, sketcher, col_maps, false,
        )
    };
    let results: Vec<Result<(Selection, f64, f64)>> = if parallel && problems.len() > 1 {
        par::map_tasks(problems, solve)
    } else {
        problems.iter().map(solve).collect()
    };
    let mut sels = Vec::with_capacity(problems.len());
    let mut sk_secs = 0.0f64;
    for r in results {
        let (sel, s, _) = r?;
        sk_secs += s;
        sels.push(sel);
    }
    Ok((sels, sk_secs))
}

/// [`solve_classes_omp`] twin for full-P solves routed through the XLA
/// correlation kernel: identical staging, targets, and merge contract
/// ([`merge_class_omp`]), but solves run serially against the (single)
/// device.
#[allow(clippy::too_many_arguments)]
fn solve_classes_omp_xla(
    rt: &Runtime,
    model: &str,
    lambda: f32,
    eps: f32,
    stages: &[ClassStage],
    budgets: &[usize],
    targets: &[Vec<f32>],
) -> Result<Selection> {
    let live = live_classes(stages, budgets);
    let mut picks = Vec::with_capacity(live.len());
    for &cls in &live {
        let stage = &stages[cls];
        let opts = OmpOpts { k: budgets[cls], lambda, eps };
        let mut backend = XlaCorr::new(rt, model, &stage.g)?;
        let res = omp_select(&mut backend, &|j| stage.g.row(j).to_vec(), &targets[cls], opts)?;
        picks.push((cls, res));
    }
    Ok(merge_class_omp(stages, picks))
}

/// Per-class matching targets over staged gradients: the staged
/// train-side full-P means, optionally overridden per class by
/// validation means (`L = L_V` rounds), sliced to the stage width.
/// Stateless — the piece both the [`GradMatch`] strategy and the
/// engine's oracle path consume, so their targets cannot drift.
pub fn staged_targets(
    stages: &[ClassStage],
    h: usize,
    c: usize,
    per_gradient: bool,
    val_means: Option<&[Option<Vec<f32>>]>,
) -> Vec<Vec<f32>> {
    let mut targets = Vec::with_capacity(stages.len());
    for (cls, stage) in stages.iter().enumerate() {
        let full: &[f32] = match val_means.and_then(|v| v[cls].as_deref()) {
            Some(vm) => vm,
            None => &stage.target_full,
        };
        if per_gradient {
            let cols = grads::class_columns(h, c, cls);
            targets.push(cols.iter().map(|&j| full[j]).collect());
        } else {
            targets.push(full.to_vec());
        }
    }
    targets
}

/// Per-class facility-location solves over staged gradients (CRAIG's
/// per-class arm): pure CPU — pairwise distances, coverage commits, and
/// medoid votes inside each task degrade to serial via the par depth
/// guard — fanned out when that beats kernel threading.  Returns the
/// class-order-merged selection and the fan-out decision.
pub fn solve_classes_fl(
    stages: &[ClassStage],
    budgets: &[usize],
    parallel: bool,
) -> (Selection, bool) {
    let sizes: Vec<usize> = stages.iter().map(|s| s.rows.len()).collect();
    let live = live_by_sizes(&sizes, budgets);
    let solve = |cls: &usize| -> Vec<(usize, f32)> {
        let stage = &stages[*cls];
        let dist = crate::par::pairwise_sqdist(&stage.g);
        let mut fl = FacilityLocation::from_sqdist(&dist);
        let res = lazy_greedy(&mut fl, budgets[*cls]);
        let w = fl.medoid_weights(&res.selected);
        res.selected.iter().zip(w).map(|(&j, wi)| (stage.rows[j], wi)).collect()
    };
    // dominant inner kernel: the O(n_c²·w/2) pairwise build
    let max_work = live
        .iter()
        .map(|&cls| sizes[cls] * sizes[cls] / 2 * stages[cls].g.cols)
        .max()
        .unwrap_or(0);
    let fan = parallel && par::fanout_wins(live.len(), max_work);
    let picked: Vec<Vec<(usize, f32)>> = solve_per_class(&live, fan, solve);
    // deterministic merge in class order
    let mut out = Selection::default();
    for class_picks in picked {
        for (row, w) in class_picks {
            out.push(row, w);
        }
    }
    (out, fan)
}

/// GLISTER's per-class proportional top-k over streamed Taylor gains
/// (CORDS-style — plain global top-k collapses onto whichever class
/// currently has the largest aligned gradients).  `scores` come in
/// `ground` order.  Returns the selection, the per-class budgets, and
/// the fan-out decision: the per-class top-ks have no inner kernels, so
/// fan-out engages only once the biggest class is large enough to
/// amortize a thread spawn.
pub fn glister_rank(
    train: &Dataset,
    ground: &[usize],
    scores: &[f32],
    budget: usize,
) -> (Selection, Vec<usize>, bool) {
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); train.classes];
    for (pos, &i) in ground.iter().enumerate() {
        per_class[train.y[i] as usize].push(pos);
    }
    let sizes: Vec<usize> = per_class.iter().map(Vec::len).collect();
    let budgets = split_budget(budget, &sizes);
    let live = live_by_sizes(&sizes, &budgets);
    let pick = |cls: &usize| -> Vec<usize> {
        let positions = &per_class[*cls];
        let class_scores: Vec<f32> = positions.iter().map(|&p| scores[p]).collect();
        top_k_desc(&class_scores, budgets[*cls])
            .into_iter()
            .map(|j| ground[positions[j]])
            .collect()
    };
    let max_class = live.iter().map(|&cls| sizes[cls]).max().unwrap_or(0);
    let fan = max_class >= (1 << 14) && live.len() > 1;
    let picked: Vec<Vec<usize>> = solve_per_class(&live, fan, pick);
    let mut out = Selection::default();
    for class_picks in picked {
        for row in class_picks {
            out.push(row, 1.0);
        }
    }
    (out, budgets, fan)
}

/// Expand a per-mini-batch OMP result back onto sample rows: every member
/// of a selected batch gets the batch weight, sum-calibrated by `scale`
/// (the PB ground size — OMP fits the mean) and split across the batch's
/// members.  The one merge contract of both PB solve arms (Rust and XLA).
pub fn expand_pb(members: &[Vec<usize>], res: &OmpResult, scale: f32) -> Selection {
    let mut out = Selection::default();
    for (slot, &b) in res.selected.iter().enumerate() {
        let w = res.weights[slot] * scale / members[b].len().max(1) as f32;
        for &row in &members[b] {
            out.push(row, w);
        }
    }
    out.grad_error = Some(res.residual_norm);
    out
}

/// The PB variants' stateless Rust solve: OMP over the batch-gradient
/// matrix, expanded through [`expand_pb`].  Pure CPU over oracle views —
/// what makes `gradmatch-pb` testable device-free.
pub fn solve_pb_omp(
    bg: &Matrix,
    members: &[Vec<usize>],
    target: &[f32],
    scale: f32,
    b_k: usize,
    lambda: f32,
    eps: f32,
) -> Result<Selection> {
    let res = omp_select_rust(bg, target, OmpOpts { k: b_k, lambda, eps })?;
    Ok(expand_pb(members, &res, scale))
}

/// Unweighted top-k selection over scored rows (ENTROPY, FORGETTING):
/// `rows[j]` enters the subset for each of the `budget` best `scores[j]`,
/// ranked by the NaN-safe [`top_k_desc`].
pub fn rank_top_k(rows: &[usize], scores: &[f32], budget: usize) -> Selection {
    let mut out = Selection::default();
    for j in top_k_desc(scores, budget) {
        out.push(rows[j], 1.0);
    }
    out
}

/// FORGETTING's cross-round state transition (Toneva et al. 2019): bump
/// `counts[idx]` on every correct→incorrect flip, then remember the new
/// correctness flags.  `correct[pos]` aligns with `rows[pos]`.
pub fn forgetting_update(
    prev_correct: &mut [f32],
    counts: &mut [f32],
    rows: &[usize],
    correct: &[f32],
) {
    for (pos, &idx) in rows.iter().enumerate() {
        if prev_correct[idx] > 0.5 && correct[pos] < 0.5 {
            counts[idx] += 1.0;
        }
        prev_correct[idx] = correct[pos];
    }
}

/// FORGETTING's ranking scores over the ground set: the forgetting count
/// plus a stable jitter so early rounds (all-zero counts) still pick a
/// spread-out subset.
pub fn forgetting_scores(counts: &[f32], ground: &[usize]) -> Vec<f32> {
    ground
        .iter()
        .map(|&i| counts[i] + 1e-6 * ((i * 2654435761) % 1000) as f32)
        .collect()
}

/// Target (mean) gradient for a scope of training rows, or — when
/// `is_valid` — for the matching validation rows of the same classes.
fn target_gradient(
    ctx: &mut SelectCtx<'_>,
    train_rows: &[usize],
    class: Option<usize>,
) -> Result<Vec<f32>> {
    if ctx.is_valid {
        let rows: Vec<usize> = match class {
            Some(c) => (0..ctx.val.len()).filter(|&i| ctx.val.y[i] as usize == c).collect(),
            None => (0..ctx.val.len()).collect(),
        };
        if rows.is_empty() {
            // no validation rows for this class — fall back to train target
            return ctx.mean_gradient(false, train_rows);
        }
        ctx.mean_gradient(true, &rows)
    } else {
        ctx.mean_gradient(false, train_rows)
    }
}

// ---------------------------------------------------------------------------
// GRAD-MATCH
// ---------------------------------------------------------------------------

/// Which GRAD-MATCH variant to run (Table 11 compares them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradMatchVariant {
    /// per-class + per-gradient (last-layer class slice) — paper default
    PerClassPerGradient,
    /// per-class on full last-layer gradients
    PerClass,
    /// per-mini-batch ground set (GRAD-MATCH-PB)
    PerBatch,
}

/// GRAD-MATCH: OMP-based gradient matching (Algorithm 1 + 2).
pub struct GradMatch {
    pub variant: GradMatchVariant,
    /// mini-batch size for the PB ground set
    pub batch: usize,
    /// route full-P correlations through the XLA/Pallas kernel
    pub use_xla: bool,
    /// run per-class rounds through the staged + fan-out engine (default);
    /// `false` pins the pre-engine serial path — one runtime pass per
    /// class, serial solves — as the equivalence baseline
    pub parallel: bool,
}

impl GradMatch {
    pub fn new(variant: GradMatchVariant, batch: usize, use_xla: bool) -> Self {
        GradMatch { variant, batch, use_xla, parallel: true }
    }

    /// Staged round: one gradient pass stages every class (through the
    /// engine's shared cache when the round is engine-driven), then the
    /// per-class OMP solves fan out (see the module docs).
    fn select_per_class(&self, ctx: &mut SelectCtx<'_>, per_gradient: bool) -> Result<Selection> {
        if !self.parallel {
            return self.select_per_class_serial(ctx, per_gradient);
        }
        if let Some(plan) = ctx.shard_plan() {
            let s = plan.shard_count(ctx.ground.len());
            if s > 1 {
                return self.select_sharded(ctx, per_gradient, plan, s);
            }
            // an effective 1-shard plan IS the flat path (bit-identical
            // by construction); just record it in the round probe
            ctx.note_shards(1, 0, ctx.ground.len());
        }
        let (h, c) = ctx.class_layout();
        let width = if per_gradient { StageWidth::ClassSlice } else { StageWidth::Full };
        let stages = ctx.class_stages(width, true)?;
        let sizes: Vec<usize> = stages.iter().map(|s| s.rows.len()).collect();
        let budgets = split_budget(ctx.budget, &sizes);
        // full-P per-class targets: free from the staged pass on the
        // train side.  When matching L_V, the val-side class means use
        // the fused `mean_grad_chunk` entry — one [P] readback per
        // populated val class, exactly the serial reference's device
        // traffic (the one-pass `grads::class_mean_gradients` twin would
        // cut dispatches but read back [chunk, P] per dispatch — see its
        // docs) — and only for classes that are live this round, so dead
        // classes (absent from the ground set or zero budget) cost zero
        // dispatches, like the serial reference.  Classes missing from
        // val fall back to the staged train target.
        let val_means = if ctx.is_valid {
            let flags = live_flags(&stages, &budgets, c);
            Some(ctx.val_class_means(&flags)?)
        } else {
            None
        };
        let targets = staged_targets(
            &stages,
            h,
            c,
            per_gradient,
            val_means.as_ref().map(|v| v.as_slice()),
        );
        // sketched solve arm: JL-project each class problem and run OMP
        // in sketch space, re-fitting weights at the staged width when the
        // plan asks for it.  An absent plan or `k ≥` the stage width falls
        // through to the flat solvers below bit-identically (nothing in
        // this block runs).  The XLA arm is bypassed under sketching —
        // sketched solves are CPU fan-out by design.
        let stage_cols = if per_gradient { h + 1 } else { h * c + c };
        if let Some(splan) = ctx.sketch_plan().filter(|pl| pl.applies(stage_cols)) {
            let sketcher = sketcher_for(ctx.rng, &splan);
            let col_maps = sketch_col_maps(h, c, per_gradient, h * c + c);
            ctx.note_round(&budgets, sketched_fanout_wins(&stages, &budgets, splan.width));
            let (sel, sk_secs, rf_secs) = solve_classes_omp_sketched(
                &stages, &budgets, &targets, ctx.lambda, ctx.eps, true, None, &sketcher,
                &col_maps, splan.refit,
            )?;
            ctx.note_sketch(splan.width, sk_secs, rf_secs);
            return Ok(sel);
        }
        if !per_gradient && self.use_xla {
            if let Some((rt, state)) = ctx.live() {
                // full-P solves through the device kernel: the staged pass
                // still replaces the C gradient + C target passes, but the
                // solves stay serial — the device is one resource, and
                // fanning out would only queue on it.  Oracle-backed rounds
                // fall through to the Rust solver below.
                ctx.note_round(&budgets, false);
                return solve_classes_omp_xla(
                    rt,
                    &state.meta.name,
                    ctx.lambda,
                    ctx.eps,
                    &stages,
                    &budgets,
                    &targets,
                );
            }
        }
        ctx.note_round(&budgets, omp_fanout_wins(&stages, &budgets));
        solve_classes_omp(&stages, &budgets, &targets, ctx.lambda, ctx.eps, true)
    }

    /// Two-level hierarchical OMP over a sharded ground set (GreeDi-style
    /// shard-then-merge; the ROADMAP's million-sample path).  The ground
    /// set splits into `s` contiguous shards ([`grads::shard_bounds`]);
    /// each shard is staged independently through the oracle seam and
    /// solved as its own per-class Batch-OMP problem, nominating an
    /// oversampled share of the budget into a merge pool.  A second-level
    /// round re-stages only the shard winners and runs the final
    /// OMP/weight-refit against the GLOBAL class-mean targets
    /// (accumulated during shard staging — no extra dispatches), with
    /// weights calibrated to the full class sizes.
    ///
    /// Memory: with `max_staged_rows` set, shards are staged in waves
    /// that fit under the budget — peak simultaneously staged rows never
    /// exceeds it (as long as the budget itself fits).  With waves of
    /// one shard, each slot recycles the previous shard's buffers.
    ///
    /// The device (XLA) solve arm is bypassed under sharding: shard
    /// solves are CPU fan-out by design, and the merge pool is small.
    fn select_sharded(
        &self,
        ctx: &mut SelectCtx<'_>,
        per_gradient: bool,
        plan: ShardPlan,
        s: usize,
    ) -> Result<Selection> {
        /// each shard nominates up to this multiple of its proportional
        /// budget share into the merge pool — oversampling keeps the
        /// merge round a real contest instead of a pass-through
        const SHARD_OVERSAMPLE: usize = 2;

        let (h, c) = ctx.class_layout();
        let p = h * c + c;
        let width = if per_gradient { StageWidth::ClassSlice } else { StageWidth::Full };
        let ground = ctx.ground;
        let n = ground.len();
        let bounds = grads::shard_bounds(n, s);
        let shard_sizes: Vec<usize> = bounds.iter().map(|&(a, b)| b - a).collect();
        let max_shard = shard_sizes.iter().copied().max().unwrap_or(0).max(1);
        // shards staged (and solved) simultaneously: as many as fit
        // under the memory budget; unbounded plans stage everything at
        // once and fan the shard solves across the machine
        let wave = if plan.max_staged_rows == 0 {
            s
        } else {
            (plan.max_staged_rows / max_shard).max(1).min(s)
        };
        // merge-pool size: oversampled budget, capped by the memory
        // budget (the winner re-stage must fit too), floored at the
        // budget itself so the final solve can always fill it
        let cap = if plan.max_staged_rows > 0 { plan.max_staged_rows } else { usize::MAX };
        let pool_target = ctx
            .budget
            .saturating_mul(SHARD_OVERSAMPLE)
            .min(n)
            .min(cap)
            .max(ctx.budget.min(n));
        let shard_budgets = split_budget(pool_target, &shard_sizes);
        // L_V targets are global: one val-means pass for every class
        // present in the ground set, shared by the shard solves and the
        // merge round
        let val_means = if ctx.is_valid {
            let mut flags = vec![false; c];
            for &i in ground {
                flags[ctx.train.y[i] as usize] = true;
            }
            Some(ctx.val_class_means(&flags)?)
        } else {
            None
        };
        let val_slice = val_means.as_ref().map(|v| v.as_slice());

        // sketch × shard composition: per-shard nomination solves run in
        // sketch space (their weights are discarded anyway), the merge
        // solve below stays full width — it IS the composition's re-fit.
        // Sketching never adds dispatches: it reads the staged buffers.
        let stage_cols = if per_gradient { h + 1 } else { p };
        let sketch = ctx.sketch_plan().filter(|pl| pl.applies(stage_cols));
        let sketcher = sketch.map(|pl| sketcher_for(ctx.rng, &pl));
        let col_maps = sketch.map(|_| sketch_col_maps(h, c, per_gradient, p));
        let mut sketch_secs = 0.0f64;

        // full-ground per-class target accumulation (f64, mirroring the
        // flat staging pass): each shard's class mean re-weighted by its
        // class row count, so the merge round matches the global class
        // means without an extra dispatch pass
        let mut acc: Vec<Vec<f64>> = vec![vec![0.0f64; p]; c];
        let mut counts = vec![0usize; c];
        let mut winners: Vec<usize> = Vec::new();
        let mut peak = 0usize;
        let mut pool: Vec<ClassStage> = Vec::new();

        let mut shard = 0usize;
        while shard < s {
            let wave_end = (shard + wave).min(s);
            let mut problems: Vec<ShardOmp> = Vec::with_capacity(wave_end - shard);
            let mut alive = 0usize;
            for si in shard..wave_end {
                let (a, b) = bounds[si];
                let prev = std::mem::take(&mut pool);
                let stages = ctx.stage_shard(&ground[a..b], width, prev)?;
                alive += b - a;
                for (cls, st) in stages.iter().enumerate() {
                    let m = st.rows.len();
                    if m > 0 {
                        for (aj, &v) in acc[cls].iter_mut().zip(st.target_full.iter()) {
                            *aj += v as f64 * m as f64;
                        }
                        counts[cls] += m;
                    }
                }
                let sizes: Vec<usize> = stages.iter().map(|st| st.rows.len()).collect();
                let budgets = split_budget(shard_budgets[si], &sizes);
                let targets = staged_targets(&stages, h, c, per_gradient, val_slice);
                problems.push(ShardOmp { stages, budgets, targets });
            }
            peak = peak.max(alive);
            let sels = match (&sketcher, &col_maps) {
                (Some(sk), Some(maps)) => {
                    let (sels, secs) = solve_shards_omp_sketched(
                        &problems,
                        ctx.lambda,
                        ctx.eps,
                        problems.len() > 1,
                        sk,
                        maps,
                    )?;
                    sketch_secs += secs;
                    sels
                }
                _ => solve_shards_omp(&problems, ctx.lambda, ctx.eps, problems.len() > 1)?,
            };
            for sel in sels {
                winners.extend(sel.indices);
            }
            // recycle the last staged shard's buffers into the next slot
            if wave == 1 {
                if let Some(last) = problems.pop() {
                    pool = last.stages;
                }
            }
            shard = wave_end;
        }

        // merge round: re-stage only the shard winners (one more
        // ⌈|winners|/chunk⌉ dispatch pass, recycling the last shard's
        // buffers) and refit over the reduced pool
        let merge_candidates = winners.len();
        peak = peak.max(merge_candidates);
        let mut mstages = ctx.stage_shard(&winners, width, std::mem::take(&mut pool))?;
        for (cls, st) in mstages.iter_mut().enumerate() {
            if counts[cls] > 0 && !st.target_full.is_empty() {
                st.target_full =
                    acc[cls].iter().map(|&v| (v / counts[cls] as f64) as f32).collect();
            }
        }
        let msizes: Vec<usize> = mstages.iter().map(|st| st.rows.len()).collect();
        let mbudgets = split_budget(ctx.budget, &msizes);
        let mtargets = staged_targets(&mstages, h, c, per_gradient, val_slice);
        // weights calibrate to the FULL class sizes (the flat path's
        // ×n_c), not the winner-pool sizes
        let scales: Vec<f32> = counts.iter().map(|&m| m as f32).collect();
        ctx.note_shards(s, merge_candidates, peak);
        if let Some(pl) = sketch {
            // refit_secs stays 0: the full-width merge solve below is the
            // composition's re-fit, and it is already on the solve clock
            ctx.note_sketch(pl.width, sketch_secs, 0.0);
        }
        ctx.note_round(&mbudgets, omp_fanout_wins(&mstages, &mbudgets));
        solve_classes_omp_scaled(
            &mstages,
            &mbudgets,
            &mtargets,
            ctx.lambda,
            ctx.eps,
            true,
            Some(&scales),
        )
    }

    /// Pre-engine reference: one padded gradient pass **per class**, a
    /// second target pass per class, serial solves.  Pinned by the
    /// round-engine property tests and benchmarked as the serial-classes
    /// baseline — do not fold into the staged path.
    pub fn select_per_class_serial(
        &self,
        ctx: &mut SelectCtx<'_>,
        per_gradient: bool,
    ) -> Result<Selection> {
        let (h, c) = ctx.class_layout();
        let per_class = ground_per_class(ctx.train, ctx.ground);
        let sizes: Vec<usize> = per_class.iter().map(Vec::len).collect();
        let budgets = split_budget(ctx.budget, &sizes);
        let mut out = Selection::default();
        let mut err_acc = 0.0f64;
        let mut err_n = 0usize;
        for (cls, rows) in per_class.iter().enumerate() {
            let k_c = budgets[cls];
            if rows.is_empty() || k_c == 0 {
                continue;
            }
            let store = ctx.per_sample_grads(rows)?;
            let target_full = target_gradient(ctx, rows, Some(cls))?;
            let (g, target): (Matrix, Vec<f32>) = if per_gradient {
                let cols = grads::class_columns(h, c, cls);
                (store.g.gather_cols(&cols), cols.iter().map(|&j| target_full[j]).collect())
            } else {
                (store.g.clone(), target_full)
            };
            let omp_opts = OmpOpts { k: k_c, lambda: ctx.lambda, eps: ctx.eps };
            let xla_arm = if !per_gradient && self.use_xla { ctx.live() } else { None };
            let res = match xla_arm {
                Some((rt, state)) => {
                    let mut backend = XlaCorr::new(rt, &state.meta.name, &g)?;
                    omp_select(&mut backend, &|j| g.row(j).to_vec(), &target, omp_opts)?
                }
                None => omp_select_rust(&g, &target, omp_opts)?,
            };
            // OMP fits the class *mean* gradient; calibrate to the class
            // *sum* (×n_c) so weights are comparable with CRAIG's medoid
            // counts and the paper's Err(w, X) accounting (Table 9).  The
            // weighted loss normalizes, so training is scale-invariant.
            let scale = rows.len() as f32;
            for (slot, &j) in res.selected.iter().enumerate() {
                out.push(rows[j], res.weights[slot] * scale);
            }
            err_acc += res.residual_norm as f64;
            err_n += 1;
        }
        if err_n > 0 {
            out.grad_error = Some((err_acc / err_n as f64) as f32);
        }
        Ok(out)
    }

    fn select_per_batch(&self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        // deterministic-per-round shuffle defines the mini-batch ground set
        let mut order = ctx.ground.to_vec();
        ctx.rng.shuffle(&mut order);
        // fused group reduction — never materializes per-sample grads
        let (bg, members) = ctx.per_batch_grads(&order)?;
        let target = target_gradient(ctx, &order, None)?;
        let b_k = (ctx.budget / self.batch).max(1).min(bg.rows);
        // same sum-calibration as the per-class path (×n over the mean fit)
        let scale = order.len() as f32;
        if self.use_xla {
            if let Some((rt, state)) = ctx.live() {
                let omp_opts = OmpOpts { k: b_k, lambda: ctx.lambda, eps: ctx.eps };
                let mut backend = XlaCorr::new(rt, &state.meta.name, &bg)?;
                let res = omp_select(&mut backend, &|j| bg.row(j).to_vec(), &target, omp_opts)?;
                return Ok(expand_pb(&members, &res, scale));
            }
        }
        solve_pb_omp(&bg, &members, &target, scale, b_k, ctx.lambda, ctx.eps)
    }
}

impl Strategy for GradMatch {
    fn name(&self) -> String {
        match self.variant {
            GradMatchVariant::PerClassPerGradient => "gradmatch".into(),
            GradMatchVariant::PerClass => "gradmatch-perclass".into(),
            GradMatchVariant::PerBatch => "gradmatch-pb".into(),
        }
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        match self.variant {
            GradMatchVariant::PerClassPerGradient => self.select_per_class(ctx, true),
            GradMatchVariant::PerClass => self.select_per_class(ctx, false),
            GradMatchVariant::PerBatch => self.select_per_batch(ctx),
        }
    }
}

// ---------------------------------------------------------------------------
// CRAIG (facility location over gradient distances)
// ---------------------------------------------------------------------------

/// CRAIG baseline: maximize the facility-location lower bound F̂ (§3.2 /
/// Appendix B.7), weights = medoid counts.
pub struct Craig {
    pub per_batch: bool,
    pub batch: usize,
    /// route full-P pairwise distances through the XLA/Pallas kernel
    pub use_xla: bool,
    /// fan the per-class facility-location solves out across classes
    /// (default); `false` runs the identical staged problems serially
    pub parallel: bool,
}

impl Craig {
    fn sqdist_matrix(&self, ctx: &SelectCtx<'_>, g: &Matrix) -> Result<Matrix> {
        if self.use_xla {
            if let Some((rt, state)) = ctx.live() {
                let meta = &state.meta;
                if g.cols == meta.p {
                    let rows = meta.chunk;
                    let nblocks = g.rows.div_ceil(rows);
                    // pad row blocks once
                    let mut blocks = Vec::with_capacity(nblocks);
                    for bi in 0..nblocks {
                        let lo = bi * rows;
                        let hi = ((bi + 1) * rows).min(g.rows);
                        let mut m = Matrix::zeros(rows, g.cols);
                        for (slot, r) in (lo..hi).enumerate() {
                            m.row_mut(slot).copy_from_slice(g.row(r));
                        }
                        blocks.push((m, lo, hi));
                    }
                    let mut dist = Matrix::zeros(g.rows, g.rows);
                    for (ba, lo_a, hi_a) in &blocks {
                        for (bb, lo_b, hi_b) in &blocks {
                            let d = rt.sqdist_chunk(&meta.name, ba, bb)?;
                            // contiguous row-slice copies (live columns of
                            // each result row land in one memcpy, not n²
                            // element sets)
                            let live_b = hi_b - lo_b;
                            for (ia, ra) in (*lo_a..*hi_a).enumerate() {
                                dist.row_mut(ra)[*lo_b..*lo_b + live_b]
                                    .copy_from_slice(&d.row(ia)[..live_b]);
                            }
                        }
                    }
                    return Ok(dist);
                }
            }
        }
        // Rust fallback (per-gradient slices / oracle-backed rounds) —
        // parallel blocked pairwise distances
        Ok(crate::par::pairwise_sqdist(g))
    }

    fn select_ground(
        &self,
        ctx: &SelectCtx<'_>,
        g: &Matrix,
        k: usize,
    ) -> Result<(Vec<usize>, Vec<f32>)> {
        let dist = self.sqdist_matrix(ctx, g)?;
        let mut fl = FacilityLocation::from_sqdist(&dist);
        let res = lazy_greedy(&mut fl, k);
        let w = fl.medoid_weights(&res.selected);
        Ok((res.selected, w))
    }
}

impl Strategy for Craig {
    fn name(&self) -> String {
        if self.per_batch { "craig-pb".into() } else { "craig".into() }
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let mut out = Selection::default();
        if self.per_batch {
            let mut order = ctx.ground.to_vec();
            ctx.rng.shuffle(&mut order);
            let (bg, members) = ctx.per_batch_grads(&order)?;
            let b_k = (ctx.budget / self.batch).max(1).min(bg.rows);
            let (sel, w) = self.select_ground(ctx, &bg, b_k)?;
            for (slot, &b) in sel.iter().enumerate() {
                for &row in &members[b] {
                    out.push(row, w[slot]);
                }
            }
        } else {
            // per-class + per-gradient slices (keeps the n_c² distance
            // matrices cheap — same approximation CRAIG itself adopts):
            // one staged pass over the ground set — shared with every
            // other per-class strategy of the round when engine-driven
            // (CRAIG never matches a target; the private path skips the
            // O(n·P) target accumulation) — then the per-class
            // facility-location solves fan out via [`solve_classes_fl`].
            let stages = ctx.class_stages(StageWidth::ClassSlice, false)?;
            let sizes: Vec<usize> = stages.iter().map(|s| s.rows.len()).collect();
            let budgets = split_budget(ctx.budget, &sizes);
            let (sel, fan) = solve_classes_fl(&stages, &budgets, self.parallel);
            ctx.note_round(&budgets, fan);
            out = sel;
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// GLISTER (Taylor-approximation greedy)
// ---------------------------------------------------------------------------

/// GLISTER baseline: the Taylor approximation of the bi-level objective
/// reduces to scoring each candidate by `∇L_V(θ) · g_j` (§3.2); selection
/// is top-k, unweighted.
pub struct Glister;

impl Strategy for Glister {
    fn name(&self) -> String {
        "glister".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        // validation mean gradient (GLISTER always uses the val set)
        let val_rows: Vec<usize> = (0..ctx.val.len()).collect();
        let v = ctx.mean_gradient(true, &val_rows)?;
        // One padded pass streams every ground sample's Taylor gain
        // `g_i · ∇L_V` (⌈|ground|/chunk⌉ dispatches, O(chunk·P) transient
        // memory — the [n, P] store is never materialized); ranking is
        // the stateless [`glister_rank`].
        let scores = ctx.score_grads(&v)?;
        let (out, budgets, fan) = glister_rank(ctx.train, ctx.ground, &scores, ctx.budget);
        ctx.note_round(&budgets, fan);
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// RANDOM / FULL
// ---------------------------------------------------------------------------

/// Uniform random subset (re-sampled every selection round).
pub struct Random;

impl Strategy for Random {
    fn name(&self) -> String {
        "random".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let k = ctx.budget.min(ctx.ground.len());
        let picks = ctx.rng.sample_indices(ctx.ground.len(), k);
        let mut out = Selection::default();
        for j in picks {
            out.push(ctx.ground[j], 1.0);
        }
        Ok(out)
    }
}

/// Entire ground set — full training and the FULL-EARLYSTOP baseline (the
/// trainer handles the early-stop budget).
pub struct Full;

impl Strategy for Full {
    fn name(&self) -> String {
        "full".into()
    }

    fn is_adaptive(&self) -> bool {
        false
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let mut out = Selection::default();
        for &i in ctx.ground {
            out.push(i, 1.0);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Table-12 extra baselines
// ---------------------------------------------------------------------------

/// Max-entropy uncertainty sampling.
pub struct Entropy;

impl Strategy for Entropy {
    fn name(&self) -> String {
        "entropy".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        // one streamed eval pass over the ground set (entries come back
        // in ground order), then the NaN-safe partial top-k: a degenerate
        // (NaN) entropy never wins and never panics the round
        let ground = ctx.ground;
        let ev = ctx.eval_entries(ground)?;
        Ok(rank_top_k(ground, &ev.entropy, ctx.budget))
    }
}

/// Forgetting events (Toneva et al. 2019): count correct→incorrect flips
/// across selection rounds; select the most-forgotten samples.
pub struct Forgetting {
    prev_correct: Vec<f32>,
    counts: Vec<f32>,
    n: usize,
}

impl Forgetting {
    pub fn new() -> Self {
        Forgetting { prev_correct: Vec::new(), counts: Vec::new(), n: 0 }
    }
}

impl Default for Forgetting {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for Forgetting {
    fn name(&self) -> String {
        "forgetting".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let n_total = ctx.train.len();
        if self.n != n_total {
            self.prev_correct = vec![0.0; n_total];
            self.counts = vec![0.0; n_total];
            self.n = n_total;
        }
        // one streamed eval pass, then the stateless count transition and
        // jitter-ranked NaN-safe top-k (counts are finite by construction,
        // but the ranking shares the baseline-wide no-panic contract)
        let ground = ctx.ground;
        let ev = ctx.eval_entries(ground)?;
        forgetting_update(&mut self.prev_correct, &mut self.counts, ground, &ev.correct);
        let scores = forgetting_scores(&self.counts, ground);
        Ok(rank_top_k(ground, &scores, ctx.budget))
    }
}

/// Facility location on raw features (model-independent; Table 12).
pub struct FeatureFL;

impl Strategy for FeatureFL {
    fn name(&self) -> String {
        "featurefl".into()
    }

    fn is_adaptive(&self) -> bool {
        false // features never change — select once
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        // no gradients involved — the per-class facility-location solves
        // fan out directly over the raw feature rows
        let per_class = ground_per_class(ctx.train, ctx.ground);
        let sizes: Vec<usize> = per_class.iter().map(Vec::len).collect();
        let budgets = split_budget(ctx.budget, &sizes);
        let train = &*ctx.train;
        let live = live_by_sizes(&sizes, &budgets);
        let solve = |cls: &usize| -> Vec<(usize, f32)> {
            let rows = &per_class[*cls];
            let x = train.x.gather_rows(rows);
            let dist = crate::par::pairwise_sqdist(&x);
            let mut fl = FacilityLocation::from_sqdist(&dist);
            let res = lazy_greedy(&mut fl, budgets[*cls]);
            let w = fl.medoid_weights(&res.selected);
            res.selected.iter().zip(w).map(|(&j, wi)| (rows[j], wi)).collect()
        };
        // dominant inner kernel: the O(n_c²·d/2) pairwise build
        let max_work = live
            .iter()
            .map(|&cls| sizes[cls] * sizes[cls] / 2 * train.x.cols)
            .max()
            .unwrap_or(0);
        let fan = par::fanout_wins(live.len(), max_work);
        ctx.note_round(&budgets, fan);
        let picked: Vec<Vec<(usize, f32)>> = solve_per_class(&live, fan, solve);
        let mut out = Selection::default();
        for class_picks in picked {
            for (row, w) in class_picks {
                out.push(row, w);
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// spec parsing
// ---------------------------------------------------------------------------

/// Parse a strategy spec like `gradmatch-pb-warm`.
/// Returns the strategy and whether warm-start is requested.
pub fn parse_strategy(spec: &str, batch: usize) -> Result<(Box<dyn Strategy>, bool)> {
    let mut s = spec.trim().to_lowercase();
    let warm = s.ends_with("-warm");
    if warm {
        s.truncate(s.len() - "-warm".len());
    }
    let b: Box<dyn Strategy> = match s.as_str() {
        "gradmatch" => Box::new(GradMatch::new(GradMatchVariant::PerClassPerGradient, batch, true)),
        "gradmatch-perclass" => Box::new(GradMatch::new(GradMatchVariant::PerClass, batch, true)),
        "gradmatch-pb" => Box::new(GradMatch::new(GradMatchVariant::PerBatch, batch, true)),
        "gradmatch-rust" => Box::new(GradMatch::new(GradMatchVariant::PerClassPerGradient, batch, false)),
        "gradmatch-pb-rust" => Box::new(GradMatch::new(GradMatchVariant::PerBatch, batch, false)),
        "craig" => Box::new(Craig { per_batch: false, batch, use_xla: false, parallel: true }),
        "craig-pb" => Box::new(Craig { per_batch: true, batch, use_xla: true, parallel: true }),
        "glister" => Box::new(Glister),
        "random" => Box::new(Random),
        "full" | "full-earlystop" => Box::new(Full),
        "entropy" => Box::new(Entropy),
        "forgetting" => Box::new(Forgetting::new()),
        "featurefl" => Box::new(FeatureFL),
        other => {
            return Err(anyhow!(
                "unknown strategy '{other}' (from spec '{spec}'); valid specs: {} — append \
                 -warm to any of them for the κ warm-start variants (paper Fig. 3 sweeps use: {})",
                strategy_specs().join(", "),
                paper_strategies().join(", ")
            ))
        }
    };
    Ok((b, warm))
}

/// Every base strategy spec [`parse_strategy`] accepts (the optional
/// `-warm` suffix composes with each).  The `gradmatch list-strategies`
/// CLI subcommand and the unknown-spec error render this list.
pub fn strategy_specs() -> Vec<&'static str> {
    vec![
        "gradmatch",
        "gradmatch-perclass",
        "gradmatch-pb",
        "gradmatch-rust",
        "gradmatch-pb-rust",
        "craig",
        "craig-pb",
        "glister",
        "random",
        "full",
        "full-earlystop",
        "entropy",
        "forgetting",
        "featurefl",
    ]
}

/// All strategy specs the paper's Figure 3 sweeps compare.
pub fn paper_strategies() -> Vec<&'static str> {
    vec![
        "random", "random-warm",
        "glister", "glister-warm",
        "craig", "craig-warm", "craig-pb", "craig-pb-warm",
        "gradmatch", "gradmatch-warm", "gradmatch-pb", "gradmatch-pb-warm",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_budget_exact_and_proportional() {
        let b = split_budget(10, &[50, 30, 20]);
        assert_eq!(b.iter().sum::<usize>(), 10);
        assert_eq!(b, vec![5, 3, 2]);
    }

    #[test]
    fn split_budget_handles_remainders() {
        let b = split_budget(10, &[33, 33, 34]);
        assert_eq!(b.iter().sum::<usize>(), 10);
        assert!(b.iter().all(|&k| (3..=4).contains(&k)));
    }

    #[test]
    fn split_budget_caps_at_class_size() {
        let b = split_budget(10, &[2, 100]);
        assert_eq!(b.iter().sum::<usize>(), 10);
        assert!(b[0] <= 2);
    }

    #[test]
    fn split_budget_drains_leftovers_into_spare_capacity() {
        // only one class has spare capacity — every leftover must land
        // there, however many passes that takes
        let b = split_budget(12, &[1, 1, 1, 40]);
        assert_eq!(b.iter().sum::<usize>(), 12);
        assert!(b[..3].iter().all(|&x| x <= 1));
        // k ≥ total: saturate everything and terminate
        assert_eq!(split_budget(30, &[10, 3]), vec![10, 3]);
        // invariant sweep: Σout == min(k, Σsizes) and out[c] ≤ sizes[c]
        for k in 0..=20 {
            for sizes in [vec![0usize, 7, 2], vec![5, 5, 5], vec![1, 0, 13], vec![2, 2]] {
                let total: usize = sizes.iter().sum();
                let out = split_budget(k, &sizes);
                assert_eq!(out.iter().sum::<usize>(), k.min(total), "k={k} sizes={sizes:?}");
                assert!(out.iter().zip(&sizes).all(|(o, s)| o <= s), "k={k} sizes={sizes:?}");
            }
        }
    }

    #[test]
    fn split_budget_empty_classes() {
        let b = split_budget(6, &[0, 10, 0, 10]);
        assert_eq!(b.iter().sum::<usize>(), 6);
        assert_eq!(b[0], 0);
        assert_eq!(b[2], 0);
    }

    #[test]
    fn top_k_desc_ranks_and_survives_nan() {
        // plain ranking, ties keep the smaller index
        assert_eq!(top_k_desc(&[1.0, 5.0, 3.0, 5.0], 3), vec![1, 3, 2]);
        // NaN never wins and never panics (the old sort_by(partial_cmp
        // .unwrap()) ranking aborted the whole selection round here)
        assert_eq!(top_k_desc(&[f32::NAN, 2.0, 1.0, f32::NAN, 3.0], 3), vec![4, 1, 2]);
        // NaNs only fill slots once every real score is taken
        assert_eq!(top_k_desc(&[f32::NAN, 1.0], 2), vec![1, 0]);
        // degenerate shapes
        assert!(top_k_desc(&[], 3).is_empty());
        assert!(top_k_desc(&[1.0, 2.0], 0).is_empty());
        assert_eq!(top_k_desc(&[f32::NAN, f32::NAN], 1).len(), 1);
        // k ≥ n returns a full ranking
        assert_eq!(top_k_desc(&[2.0, 9.0, 4.0], 99), vec![1, 2, 0]);
    }

    #[test]
    fn top_k_desc_matches_full_sort_on_finite_scores() {
        use crate::testutil::forall;
        forall(30, |g| {
            let n = g.int(1, 120);
            let scores = g.gauss_vec(n);
            let k = g.int(0, n);
            let got = top_k_desc(&scores, k);
            let mut want: Vec<usize> = (0..n).collect();
            want.sort_by(|&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
            });
            want.truncate(k);
            assert_eq!(got, want, "n={n} k={k}");
        });
    }

    fn synth_stages(g: &mut crate::testutil::Gen, classes: usize, width: usize) -> Vec<ClassStage> {
        let mut next_row = 0usize;
        (0..classes)
            .map(|_| {
                let n_c = g.int(0, 40);
                let rows: Vec<usize> = (next_row..next_row + n_c).collect();
                next_row += n_c;
                ClassStage {
                    g: g.matrix(n_c, width),
                    rows,
                    target_full: g.gauss_vec(width),
                }
            })
            .collect()
    }

    #[test]
    fn class_fanout_is_pinned_to_the_serial_merge() {
        use crate::testutil::forall;
        // the engine's core contract: fan-out == serial, bit-identical
        // merge order, across class counts, widths, and imbalanced
        // budget shapes
        forall(20, |g| {
            let classes = g.int(1, 12);
            let width = g.int(2, 10);
            let stages = synth_stages(g, classes, width);
            let sizes: Vec<usize> = stages.iter().map(|s| s.rows.len()).collect();
            let budget = g.int(1, sizes.iter().sum::<usize>().max(1));
            let budgets = split_budget(budget, &sizes);
            let targets: Vec<Vec<f32>> =
                stages.iter().map(|s| s.target_full.clone()).collect();
            let serial =
                solve_classes_omp(&stages, &budgets, &targets, 0.5, 1e-12, false).unwrap();
            let fanout =
                solve_classes_omp(&stages, &budgets, &targets, 0.5, 1e-12, true).unwrap();
            assert_eq!(serial.indices, fanout.indices, "classes={classes}");
            for (a, b) in serial.weights.iter().zip(&fanout.weights) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
            assert_eq!(serial.grad_error.is_some(), fanout.grad_error.is_some());
            if let (Some(a), Some(b)) = (serial.grad_error, fanout.grad_error) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()));
            }
        });
    }

    #[test]
    fn parse_strategy_specs() {
        for spec in paper_strategies() {
            let (s, warm) = parse_strategy(spec, 128).unwrap();
            assert_eq!(warm, spec.ends_with("-warm"));
            assert!(!s.name().is_empty());
        }
        assert!(parse_strategy("bogus", 128).is_err());
        let (s, warm) = parse_strategy("gradmatch-pb-warm", 32).unwrap();
        assert!(warm);
        assert_eq!(s.name(), "gradmatch-pb");
        let (s, _) = parse_strategy("FULL-EARLYSTOP", 32).unwrap();
        assert_eq!(s.name(), "full");
        assert!(!s.is_adaptive());
    }

    #[test]
    fn every_listed_spec_parses_plain_and_warm() {
        for spec in strategy_specs() {
            let (st, warm) = parse_strategy(spec, 64).unwrap();
            assert!(!warm, "{spec}");
            assert!(!st.name().is_empty(), "{spec}");
            let (_, warm) = parse_strategy(&format!("{spec}-warm"), 64).unwrap();
            assert!(warm, "{spec}-warm");
        }
    }

    #[test]
    fn unknown_spec_error_lists_valid_specs() {
        let err = parse_strategy("bogus", 128).unwrap_err().to_string();
        for spec in strategy_specs() {
            assert!(err.contains(spec), "error should name '{spec}': {err}");
        }
        assert!(err.contains("-warm"), "error should mention the warm suffix: {err}");
    }
}
