//! Solvers for the OMP inner problem (substrate — no LAPACK offline).
//!
//! GRAD-MATCH's weight re-fit (Algorithm 2, line `w ← argmin Errλ`) is a
//! ridge-regularized least squares over the selected gradient rows:
//!
//! ```text
//!   w* = argmin_w ‖ G_Sᵀ w − g_target ‖² + λ‖w‖²
//!      = (G_S G_Sᵀ + λI)⁻¹ G_S g_target
//! ```
//!
//! with `|S| = k` small (≤ a few hundred), so dense Cholesky on the k×k
//! normal matrix is the right tool.  [`CholFactor::extend`] supports the
//! OMP hot path: when one row joins the support, the factor is updated in
//! O(k²) instead of re-factorized in O(k³).

use crate::tensor::{dot, gemv_t, Matrix};

/// Error type for solver failures (non-SPD systems etc.).
#[derive(Debug)]
pub enum LinalgError {
    NotPositiveDefinite(usize, f64),
    Dimension(String),
}

impl std::fmt::Display for LinalgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LinalgError::NotPositiveDefinite(pivot, v) => {
                write!(f, "matrix not positive definite at pivot {pivot} (value {v})")
            }
            LinalgError::Dimension(d) => write!(f, "dimension mismatch: {d}"),
        }
    }
}

impl std::error::Error for LinalgError {}

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`, kept in f64 for
/// stability (the gram entries come from f32 gradient dot products).
#[derive(Clone, Debug)]
pub struct CholFactor {
    n: usize,
    /// row-major lower triangle, full n×n storage
    l: Vec<f64>,
}

impl CholFactor {
    /// Factor a dense SPD matrix given row-major (f32) data.
    pub fn factor(a: &Matrix) -> Result<Self, LinalgError> {
        if a.rows != a.cols {
            return Err(LinalgError::Dimension(format!("{}x{}", a.rows, a.cols)));
        }
        let n = a.rows;
        let mut f = CholFactor { n: 0, l: Vec::new() };
        // build incrementally via extend — one code path to test
        for j in 0..n {
            let col: Vec<f64> = (0..=j).map(|i| a.at(j, i) as f64).collect();
            f.extend(&col)?;
        }
        Ok(f)
    }

    /// Empty factor for incremental construction.
    pub fn empty() -> Self {
        CholFactor { n: 0, l: Vec::new() }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Grow the factor by one row/column of the underlying SPD matrix.
    ///
    /// `new_row` is the new matrix row `A[n, 0..=n]` (length n+1, last
    /// element the diagonal).  O(n²).
    pub fn extend(&mut self, new_row: &[f64]) -> Result<(), LinalgError> {
        let n = self.n;
        if new_row.len() != n + 1 {
            return Err(LinalgError::Dimension(format!(
                "extend: expected {} entries, got {}",
                n + 1,
                new_row.len()
            )));
        }
        // Re-pack into (n+1)x(n+1) storage.
        let m = n + 1;
        let mut l = vec![0.0f64; m * m];
        for i in 0..n {
            l[i * m..i * m + n].copy_from_slice(&self.l[i * n..i * n + n]);
        }
        // forward-solve L x = new_row[..n]
        for j in 0..n {
            let mut v = new_row[j];
            for k in 0..j {
                v -= l[n * m + k] * l[j * m + k];
            }
            l[n * m + j] = v / l[j * m + j];
        }
        let mut diag = new_row[n];
        for k in 0..n {
            diag -= l[n * m + k] * l[n * m + k];
        }
        if diag <= 0.0 || !diag.is_finite() {
            return Err(LinalgError::NotPositiveDefinite(n, diag));
        }
        l[n * m + n] = diag.sqrt();
        self.l = l;
        self.n = m;
        Ok(())
    }

    /// Solve `A x = b` via forward/backward substitution.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>, LinalgError> {
        if b.len() != self.n {
            return Err(LinalgError::Dimension(format!(
                "solve: {} vs {}",
                b.len(),
                self.n
            )));
        }
        let n = self.n;
        let l = &self.l;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut v = b[i];
            for k in 0..i {
                v -= l[i * n + k] * y[k];
            }
            y[i] = v / l[i * n + i];
        }
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut v = y[i];
            for k in i + 1..n {
                v -= l[k * n + i] * x[k];
            }
            x[i] = v / l[i * n + i];
        }
        Ok(x)
    }
}

/// Solve the ridge-regularized gradient-matching weights for a support.
///
/// `g_sel` holds the selected gradient rows (`k × p`), `target` the gradient
/// to match (`p`).  Returns `w` with `‖G_selᵀ w − target‖² + λ‖w‖²` minimal.
pub fn ridge_weights(g_sel: &Matrix, target: &[f32], lambda: f32) -> Result<Vec<f32>, LinalgError> {
    if g_sel.cols != target.len() {
        return Err(LinalgError::Dimension(format!(
            "ridge: {} vs {}",
            g_sel.cols,
            target.len()
        )));
    }
    let k = g_sel.rows;
    // parallel blocked Gram build — the O(k²·P) piece of every re-fit
    let mut a = crate::par::gram(g_sel);
    for i in 0..k {
        a.data[i * k + i] += lambda;
    }
    let f = CholFactor::factor(&a)?;
    let rhs: Vec<f64> = (0..k).map(|i| dot(g_sel.row(i), target) as f64).collect();
    Ok(f.solve(&rhs)?.into_iter().map(|v| v as f32).collect())
}

/// Non-negative ridge weights via iterated clamp-and-re-solve (a simplified
/// active-set NNLS in the spirit of Lawson–Hanson): solve the ridge system,
/// drop negative-weight rows from the support, re-solve, and repeat until
/// the support is feasible.  Terminates in ≤ k passes since the support
/// shrinks monotonically.  Keeps weights interpretable as per-sample
/// importance (matches CORDS' non-negative OMP).
pub fn ridge_weights_nonneg(
    g_sel: &Matrix,
    target: &[f32],
    lambda: f32,
) -> Result<Vec<f32>, LinalgError> {
    let k = g_sel.rows;
    let mut support: Vec<usize> = (0..k).collect();
    loop {
        if support.is_empty() {
            return Ok(vec![0.0; k]);
        }
        let sub = if support.len() == k {
            g_sel.clone()
        } else {
            g_sel.gather_rows(&support)
        };
        let w = ridge_weights(&sub, target, lambda)?;
        if w.iter().all(|&v| v >= 0.0) {
            let mut out = vec![0.0f32; k];
            for (slot, &i) in support.iter().enumerate() {
                out[i] = w[slot];
            }
            return Ok(out);
        }
        let next: Vec<usize> = support
            .iter()
            .zip(&w)
            .filter(|(_, &wv)| wv > 0.0)
            .map(|(&i, _)| i)
            .collect();
        if next.len() == support.len() {
            // all weights nonnegative already handled; defensive guard
            let mut out = vec![0.0f32; k];
            for (slot, &i) in support.iter().enumerate() {
                out[i] = w[slot].max(0.0);
            }
            return Ok(out);
        }
        support = next;
    }
}

/// Residual `target − G_selᵀ w` (the OMP residual vector).
pub fn residual(g_sel: &Matrix, w: &[f32], target: &[f32]) -> Vec<f32> {
    let mut fitted = vec![0.0f32; g_sel.cols];
    gemv_t(g_sel, w, &mut fitted);
    crate::tensor::sub(target, &fitted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::norm2;

    fn spd(n: usize, rng: &mut Rng) -> Matrix {
        // A = B Bᵀ + n·I is SPD
        let b = Matrix::from_vec(n, n, (0..n * n).map(|_| rng.gaussian_f32()).collect());
        let mut a = crate::tensor::gram(&b);
        for i in 0..n {
            a.data[i * n + i] += n as f32;
        }
        a
    }

    #[test]
    fn cholesky_solves_spd_systems() {
        let mut rng = Rng::new(1);
        for n in [1usize, 2, 5, 12, 40] {
            let a = spd(n, &mut rng);
            let f = CholFactor::factor(&a).unwrap();
            let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7) - 1.0).collect();
            let mut b = vec![0.0f64; n];
            for i in 0..n {
                for j in 0..n {
                    b[i] += a.at(i, j) as f64 * x_true[j];
                }
            }
            let x = f.solve(&b).unwrap();
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-3, "n={n} i={i}: {} vs {}", x[i], x_true[i]);
            }
        }
    }

    #[test]
    fn factor_rejects_non_spd() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigvals 3, -1
        assert!(CholFactor::factor(&a).is_err());
    }

    #[test]
    fn extend_matches_batch_factor() {
        let mut rng = Rng::new(2);
        let a = spd(8, &mut rng);
        let batch = CholFactor::factor(&a).unwrap();
        let mut inc = CholFactor::empty();
        for j in 0..8 {
            let row: Vec<f64> = (0..=j).map(|i| a.at(j, i) as f64).collect();
            inc.extend(&row).unwrap();
        }
        for i in 0..batch.l.len() {
            assert!((batch.l[i] - inc.l[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn ridge_weights_zero_lambda_recovers_exact_combination() {
        // target is an exact combination of rows -> tiny residual at λ→0
        let g = Matrix::from_vec(2, 4, vec![1., 0., 1., 0., 0., 1., 0., 1.]);
        let target = [2.0f32, 3.0, 2.0, 3.0]; // 2*row0 + 3*row1
        let w = ridge_weights(&g, &target, 1e-6).unwrap();
        assert!((w[0] - 2.0).abs() < 1e-3 && (w[1] - 3.0).abs() < 1e-3, "{w:?}");
        let r = residual(&g, &w, &target);
        assert!(norm2(&r) < 1e-2);
    }

    #[test]
    fn ridge_lambda_shrinks_weights() {
        let mut rng = Rng::new(3);
        let g = Matrix::from_vec(3, 10, (0..30).map(|_| rng.gaussian_f32()).collect());
        let target: Vec<f32> = (0..10).map(|_| rng.gaussian_f32()).collect();
        let w0 = ridge_weights(&g, &target, 1e-4).unwrap();
        let w1 = ridge_weights(&g, &target, 100.0).unwrap();
        assert!(norm2(&w1) < norm2(&w0));
    }

    #[test]
    fn ridge_weights_match_normal_equation_residual_orthogonality() {
        // At λ=0 the residual must be orthogonal to every selected row.
        let mut rng = Rng::new(4);
        let g = Matrix::from_vec(4, 16, (0..64).map(|_| rng.gaussian_f32()).collect());
        let target: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        let w = ridge_weights(&g, &target, 1e-7).unwrap();
        let r = residual(&g, &w, &target);
        for i in 0..4 {
            assert!(dot(g.row(i), &r).abs() < 1e-2, "row {i} not orthogonal");
        }
    }

    #[test]
    fn nonneg_weights_are_nonneg_and_no_worse_than_zero() {
        let mut rng = Rng::new(5);
        for trial in 0..20 {
            let g = Matrix::from_vec(5, 8, (0..40).map(|_| rng.gaussian_f32()).collect());
            let target: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            let w = ridge_weights_nonneg(&g, &target, 0.5).unwrap();
            assert!(w.iter().all(|&v| v >= 0.0), "trial {trial}: {w:?}");
            // fit must beat the empty fit unless all weights got clamped away
            if w.iter().any(|&v| v > 0.0) {
                let r = residual(&g, &w, &target);
                assert!(norm2(&r) <= norm2(&target) + 1e-4);
            }
        }
    }

    #[test]
    fn residual_of_zero_weights_is_target() {
        let g = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let r = residual(&g, &[0.0, 0.0], &[7.0, 8.0, 9.0]);
        assert_eq!(r, vec![7.0, 8.0, 9.0]);
    }
}
