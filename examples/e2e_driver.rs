//! End-to-end driver — the full-system validation run recorded in
//! EXPERIMENTS.md.
//!
//! Trains the `lenet_s` classifier on the full `synmnist` suite (10k train
//! samples) for a few hundred epochs under FULL training, then under
//! GRAD-MATCH-PB-WARM at 10% and 30% budgets (plus RANDOM at 10% as the
//! floor), logging the loss curve and test accuracy over wall-clock time.
//! This exercises every layer in composition: synthetic data pipeline →
//! PJRT executables built from the JAX+Pallas artifacts → gradient cache →
//! OMP selection → weighted-SGD training loop → metrics.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_driver           # full run
//! cargo run --release --example e2e_driver -- --epochs 60 --n-train 4000  # smaller
//! ```

use anyhow::Result;
use gradmatch::cli::Cli;
use gradmatch::coordinator::{write_results, Coordinator};

fn main() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    args.insert(0, "train".into());
    let cli = Cli::parse(&args)?;
    let mut cfg = cli.experiment_config()?;
    if cli.flag("epochs").is_none() {
        cfg.epochs = 200;
    }
    if cli.flag("eval-every").is_none() {
        cfg.eval_every = 10;
    }
    println!(
        "e2e driver: dataset={} model={} epochs={} n_train={} R={}",
        cfg.dataset,
        cfg.model,
        cfg.epochs,
        if cfg.n_train == 0 { 10_000 } else { cfg.n_train },
        cfg.r_interval
    );

    let mut coord = Coordinator::new(&cfg.artifacts_dir)?;
    let mut all = Vec::new();

    // FULL skyline
    let full = coord.full_baseline(&cfg, cfg.seed)?;
    println!("\n== FULL ==");
    print_convergence(&full.convergence);
    println!(
        "FULL final: acc {:.2}%  time {:.1}s  energy(sim) {:.5} kWh",
        full.test_acc * 100.0,
        full.total_secs,
        full.energy_kwh
    );
    all.push(full.clone());

    for (strat, budget) in [
        ("random", 0.10),
        ("gradmatch-pb-warm", 0.10),
        ("gradmatch-pb-warm", 0.30),
    ] {
        let mut c = cfg.clone();
        c.strategy = strat.into();
        c.budget_frac = budget;
        println!("\n== {strat} @ {:.0}% ==", budget * 100.0);
        let r = coord.run_one(&c, c.seed)?;
        print_convergence(&r.convergence);
        println!(
            "{strat} @ {:.0}% final: acc {:.2}% (rel-err {:.2}%)  time {:.1}s  speedup {:.2}x  select {:.1}s  energy-gain {:.2}x",
            budget * 100.0,
            r.test_acc * 100.0,
            100.0 * (full.test_acc - r.test_acc) / full.test_acc,
            r.total_secs,
            full.total_secs / r.total_secs.max(1e-9),
            r.select_secs,
            full.energy_kwh / r.energy_kwh.max(1e-12),
        );
        println!(
            "  selection rounds: {} ({} staging dispatches, stage {:.2}s / solve {:.2}s)",
            r.selections, r.stage_dispatches, r.select_stage_secs, r.select_solve_secs
        );
        all.push(r);
    }

    let path = write_results(&cfg.out_dir, "e2e_driver", &all)?;
    println!("\nwrote {path}");
    Ok(())
}

fn print_convergence(points: &[(usize, f64, f64)]) {
    println!("  epoch    cum-time    test-acc");
    for &(e, t, a) in points {
        println!("  {e:>5}    {t:>7.1}s    {:>6.2}%", a * 100.0);
    }
}
