//! Class-imbalance robustness (paper Fig. 3f,g / Fig. 4e): 30% of classes
//! lose 90% of their samples; strategies match the **validation** gradient
//! (`L = L_V`), since the training distribution is biased.
//!
//! ```bash
//! cargo run --release --example imbalance -- --dataset syncifar10 --budget 0.3
//! ```

use anyhow::Result;
use gradmatch::cli::Cli;
use gradmatch::coordinator::Coordinator;

fn main() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    args.insert(0, "train".into());
    let cli = Cli::parse(&args)?;
    let mut cfg = cli.experiment_config()?;
    cfg.is_valid = true; // match validation gradients — the paper's setting
    if cli.flag("epochs").is_none() {
        cfg.epochs = 60;
    }
    if cli.flag("n-train").is_none() {
        cfg.n_train = 4000;
    }
    if cli.flag("budget").is_none() {
        cfg.budget_frac = 0.3;
    }
    cfg.r_interval = cfg.r_interval.min(15);

    println!(
        "class-imbalance experiment: dataset={} budget={:.0}% (30% of classes reduced by 90%)",
        cfg.dataset,
        cfg.budget_frac * 100.0
    );
    let mut coord = Coordinator::new(&cfg.artifacts_dir)?;

    // FULL on the imbalanced data (paper: full training underperforms under
    // high imbalance because it overfits the majority classes)
    let mut full_cfg = cfg.clone();
    full_cfg.strategy = "full".into();
    full_cfg.budget_frac = 1.0;
    let full = coord.run_one(&full_cfg, cfg.seed)?;
    println!(
        "\n{:<22} acc {:>6.2}%  time {:>7.1}s",
        "full(imbalanced)",
        full.test_acc * 100.0,
        full.total_secs
    );

    for strat in [
        "random",
        "glister",
        "craig-pb",
        "gradmatch",
        "gradmatch-warm",
        "gradmatch-pb-warm",
    ] {
        let mut c = cfg.clone();
        c.strategy = strat.into();
        let r = coord.run_one(&c, c.seed)?;
        println!(
            "{strat:<22} acc {:>6.2}%  time {:>7.1}s  select {:>5.1}s (stage {:>4.1}s / solve {:>4.1}s)  speedup {:>5.2}x",
            r.test_acc * 100.0,
            r.total_secs,
            r.select_secs,
            r.select_stage_secs,
            r.select_solve_secs,
            full.total_secs / r.total_secs.max(1e-9)
        );
    }
    println!("\n(validation-gradient matching enabled: L = L_V)");
    Ok(())
}
