//! Extended training (paper Fig. 3k): run GRAD-MATCH-PB-WARM at a 30%
//! budget for the standard schedule, then keep training past the standard
//! endpoint and report when it reaches parity with full training — the
//! paper finds parity ~30–50 extra epochs while remaining ≈2.5× faster.
//!
//! ```bash
//! cargo run --release --example extended_training -- --epochs 60 --n-train 4000
//! ```

use anyhow::Result;
use gradmatch::cli::Cli;
use gradmatch::coordinator::Coordinator;

fn main() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    args.insert(0, "train".into());
    let cli = Cli::parse(&args)?;
    let mut cfg = cli.experiment_config()?;
    if cli.flag("epochs").is_none() {
        cfg.epochs = 60;
    }
    if cli.flag("n-train").is_none() {
        cfg.n_train = 4000;
    }
    if cli.flag("budget").is_none() {
        cfg.budget_frac = 0.30;
    }
    cfg.eval_every = cfg.eval_every.max(5);
    cfg.strategy = "gradmatch-pb-warm".into();

    println!(
        "extended training: dataset={} budget={:.0}% standard endpoint T={}",
        cfg.dataset,
        cfg.budget_frac * 100.0,
        cfg.epochs
    );
    let mut coord = Coordinator::new(&cfg.artifacts_dir)?;
    let full = coord.full_baseline(&cfg, cfg.seed)?;
    println!(
        "full training: acc {:.2}% in {:.1}s",
        full.test_acc * 100.0,
        full.total_secs
    );

    // standard schedule
    let std_run = coord.run_one(&cfg, cfg.seed)?;
    println!(
        "standard endpoint (*): acc {:.2}% in {:.1}s (speedup {:.2}x; {} selection rounds: stage {:.2}s / solve {:.2}s)",
        std_run.test_acc * 100.0,
        std_run.total_secs,
        full.total_secs / std_run.total_secs.max(1e-9),
        std_run.selections,
        std_run.select_stage_secs,
        std_run.select_solve_secs
    );

    // extend by up to ~80% more epochs, reporting the convergence tail
    let mut ext_cfg = cfg.clone();
    ext_cfg.epochs = cfg.epochs + (cfg.epochs * 4) / 5;
    let ext = coord.run_one(&ext_cfg, cfg.seed)?;
    println!("\nextended convergence (test-acc vs cumulative time):");
    let mut parity: Option<(usize, f64)> = None;
    for &(e, t, a) in &ext.convergence {
        let marker = if e + 1 == cfg.epochs { "  <- standard endpoint (*)" } else { "" };
        println!("  epoch {e:>4}  {t:>7.1}s  {:>6.2}%{marker}", a * 100.0);
        if parity.is_none() && a >= full.test_acc - 1e-6 {
            parity = Some((e, t));
        }
    }
    match parity {
        Some((e, t)) => println!(
            "\nreached full-training parity at epoch {e} ({:.1}s) — overall {:.2}x faster than full",
            t,
            full.total_secs / t.max(1e-9)
        ),
        None => println!(
            "\nfinal extended acc {:.2}% vs full {:.2}% — gap {:.2}pp after {} epochs",
            ext.test_acc * 100.0,
            full.test_acc * 100.0,
            (full.test_acc - ext.test_acc) * 100.0,
            ext_cfg.epochs
        ),
    }
    Ok(())
}
