//! Shared fixtures for the integration tests.

use gradmatch::data::{DatasetCard, Splits};
use gradmatch::runtime::Runtime;

/// Artifact dir for tests — honors `GRADMATCH_ARTIFACTS`.
pub fn artifacts_dir() -> String {
    std::env::var("GRADMATCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Shared runtime (compiling executables once per test binary).  Call
/// only after [`runtime_available`] returned true.
pub fn runtime() -> Runtime {
    Runtime::load(artifacts_dir()).expect("artifacts missing — run `make artifacts`")
}

/// Whether the PJRT runtime + HLO artifacts can actually load.  The
/// integration tests early-return (skip) when they cannot — e.g. on the
/// pure-host `xla` stub build or before `make artifacts` — so
/// `cargo test` stays green everywhere while still exercising the full
/// contract when the real backend is present.  Probed once per test
/// binary (the probe constructs and drops a runtime; caching keeps it
/// off every test's clock).
pub fn runtime_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| match Runtime::load(artifacts_dir()) {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping: runtime unavailable ({e:#})");
            false
        }
    })
}

/// Small lenet_s-compatible dataset (784-dim) for fast integration runs.
pub fn tiny_mnist(n: usize) -> Splits {
    let card = DatasetCard::by_name("synmnist").unwrap();
    card.generate(7, n)
}

pub fn assert_close(a: f32, b: f32, tol: f32, what: &str) {
    assert!(
        (a - b).abs() <= tol,
        "{what}: {a} vs {b} (tol {tol})"
    );
}
