//! Parallel blocked compute layer for the selection hot path.
//!
//! The coordinator-side kernels in [`crate::tensor`] are deliberately
//! plain — they are the *reference* implementations the runtime tests and
//! the property tests compare against.  This module provides the
//! *production* versions the hot paths call:
//!
//! - [`dot`] / [`sqdist`] — 4-accumulator unrolled inner loops (f64
//!   accumulation, same as the reference, but the independent lanes let
//!   the CPU overlap the FMA chains instead of serializing on one
//!   accumulator);
//! - [`gemv`] — chunked row-parallel GEMV over scoped threads (the OMP
//!   ground-set correlation `G·v`, the Batch-OMP Gram columns `G·g_s`,
//!   the batched Cholesky-extend support dots, and GLISTER's Taylor
//!   scores);
//! - [`gram`] / [`pairwise_sqdist`] — symmetric pairwise builds with
//!   row-level work stealing (an atomic cursor hands out rows, so the
//!   shrinking-triangle imbalance is absorbed), used by the ridge re-fit
//!   normal matrix and the CRAIG / facility-location similarity builds;
//! - [`colsum_pos`] — clamped column sums, the facility-location initial
//!   gains (`cover = 0`), parallel over column blocks;
//! - [`map_tasks`] / [`for_chunks`] — the *task* substrate of the parallel
//!   selection-round engine: coarse class-level closures fan out across
//!   scoped workers with work stealing and deterministic (input-order)
//!   results.
//!
//! # Two levels of parallelism, one machine
//!
//! The selection round exposes parallelism at two altitudes: *inside* a
//! kernel (rows of one GEMV) and *across classes* (independent per-class
//! OMP / facility-location solves).  Running both at once oversubscribes
//! the cores, so every worker spawned by [`map_tasks`] is marked with a
//! thread-local depth flag ([`in_task`]) and every policy-driven kernel
//! entry point ([`gemv`], [`gram`], [`pairwise_sqdist`], [`colsum_pos`],
//! [`for_chunks`], nested [`map_tasks`]) degrades to its serial path when
//! the flag is set.  Class-level fan-out therefore *replaces* — never
//! multiplies — kernel-level threading, and the results are identical
//! either way (each output element is computed by exactly one worker with
//! the same arithmetic).
//!
//! Everything is std-only (`std::thread::scope`), allocation-free in the
//! inner loops, and falls back to single-thread execution below a
//! flop threshold so tiny per-class slices don't pay spawn overhead.
//! Thread count comes from `available_parallelism`, overridable with
//! `GRADMATCH_THREADS=<n>` (set `1` to force the serial path, e.g. for
//! bit-stable A/B runs).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::tensor::Matrix;

/// Mul-adds below which threading costs more than it saves.
const PAR_MIN_FLOPS: usize = 1 << 16;

/// Worker-thread count: `GRADMATCH_THREADS` override, else the machine.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("GRADMATCH_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

thread_local! {
    /// Set on [`map_tasks`] worker threads for the worker's lifetime.
    static IN_TASK: Cell<bool> = Cell::new(false);
}

/// Whether the current thread is a class-level task worker.  Inner
/// policy-driven kernels consult this to take their serial paths instead
/// of oversubscribing the machine with nested spawns.
pub fn in_task() -> bool {
    IN_TASK.with(|c| c.get())
}

/// Thread count policy for a kernel of `work` mul-adds: serial below the
/// flop floor or inside a class-level task, else the machine.
pub(crate) fn policy_threads(work: usize) -> usize {
    if in_task() || work < PAR_MIN_FLOPS {
        1
    } else {
        num_threads()
    }
}

// ---------------------------------------------------------------------------
// class-level task fan-out
// ---------------------------------------------------------------------------

/// Run `f` over every item on `threads` scoped workers with an atomic
/// work-stealing cursor; results come back in **input order** regardless
/// of which worker ran which item, so merges downstream are
/// deterministic.  Workers carry the [`in_task`] depth flag.  Exposed for
/// tests; use [`map_tasks`] for the policy-driven entry point.
pub fn map_tasks_threads<I: Sync, T: Send>(
    items: &[I],
    threads: usize,
    f: impl Fn(&I) -> T + Sync,
) -> Vec<T> {
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 || n <= 1 {
        return items.iter().map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                IN_TASK.with(|c| c.set(true));
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = f(&items[i]);
                    *slots[i].lock().unwrap() = Some(out);
                }
                IN_TASK.with(|c| c.set(false));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every task slot is filled"))
        .collect()
}

/// Policy-driven [`map_tasks_threads`]: class-level fan-out across the
/// machine, degrading to a plain serial map when already inside a task
/// (no nested fan-out) or when only one worker is available.  Tasks are
/// assumed coarse (a whole per-class solve), so there is no flop floor.
pub fn map_tasks<I: Sync, T: Send>(items: &[I], f: impl Fn(&I) -> T + Sync) -> Vec<T> {
    let threads = if in_task() { 1 } else { num_threads() };
    map_tasks_threads(items, threads, f)
}

/// Apply `f(lo, chunk)` to disjoint contiguous chunks of `out` on
/// `threads` scoped workers (`lo` is the chunk's start offset in `out`).
/// Exposed for tests; use [`for_chunks`] for the policy entry point.
pub fn for_chunks_threads<T: Send>(
    out: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let n = out.len();
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        f(0, out);
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (blk, chunk) in out.chunks_mut(per).enumerate() {
            let lo = blk * per;
            let fr = &f;
            s.spawn(move || fr(lo, chunk));
        }
    });
}

/// Policy-driven [`for_chunks_threads`] for an elementwise pass costing
/// `work` mul-adds total (e.g. facility-location coverage updates).
pub fn for_chunks<T: Send>(out: &mut [T], work: usize, f: impl Fn(usize, &mut [T]) + Sync) {
    for_chunks_threads(out, policy_threads(work), f);
}

/// Whether fanning `tasks` coarse tasks out beats keeping kernel-level
/// threading.  Fan-out workers run their inner kernels serially (the
/// depth guard), so it only wins when the tasks alone can occupy every
/// worker — or when the largest task sits below the kernel-parallel flop
/// floor anyway, in which case its inner kernels would run serial in
/// either mode and fan-out is free concurrency.  `max_task_work` is the
/// largest single inner-kernel cost (mul-adds) across the tasks.
pub fn fanout_wins(tasks: usize, max_task_work: usize) -> bool {
    tasks > 1 && (tasks >= num_threads() || max_task_work < PAR_MIN_FLOPS)
}

// ---------------------------------------------------------------------------
// unrolled scalar kernels
// ---------------------------------------------------------------------------

/// Dot product with 4 independent f64 accumulator lanes.
///
/// Same precision model as the reference [`crate::tensor::dot`] (every
/// product is taken in f64); the lanes only change the summation order,
/// so results agree to f32 round-off.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let n4 = n - n % 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i < n4 {
        s0 += a[i] as f64 * b[i] as f64;
        s1 += a[i + 1] as f64 * b[i + 1] as f64;
        s2 += a[i + 2] as f64 * b[i + 2] as f64;
        s3 += a[i + 3] as f64 * b[i + 3] as f64;
        i += 4;
    }
    let mut tail = 0.0f64;
    while i < n {
        tail += a[i] as f64 * b[i] as f64;
        i += 1;
    }
    (((s0 + s1) + (s2 + s3)) + tail) as f32
}

/// Euclidean norm via the unrolled dot.
#[inline]
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// Squared euclidean distance with 4 accumulator lanes.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let n4 = n - n % 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i < n4 {
        let d0 = (a[i] - b[i]) as f64;
        let d1 = (a[i + 1] - b[i + 1]) as f64;
        let d2 = (a[i + 2] - b[i + 2]) as f64;
        let d3 = (a[i + 3] - b[i + 3]) as f64;
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
        i += 4;
    }
    let mut tail = 0.0f64;
    while i < n {
        let d = (a[i] - b[i]) as f64;
        tail += d * d;
        i += 1;
    }
    (((s0 + s1) + (s2 + s3)) + tail) as f32
}

// ---------------------------------------------------------------------------
// row-parallel GEMV
// ---------------------------------------------------------------------------

/// `out = M v`, rows split into contiguous blocks across `threads`
/// scoped workers.  Exposed for the property tests; use [`gemv`] for the
/// policy-driven entry point.
pub fn gemv_threads(m: &Matrix, v: &[f32], out: &mut [f32], threads: usize) {
    assert_eq!(m.cols, v.len(), "gemv: cols vs v");
    assert_eq!(m.rows, out.len(), "gemv: rows vs out");
    if m.rows == 0 {
        return;
    }
    let threads = threads.clamp(1, m.rows);
    if threads == 1 {
        for (r, o) in out.iter_mut().enumerate() {
            *o = dot(m.row(r), v);
        }
        return;
    }
    let rows_per = m.rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (blk, chunk) in out.chunks_mut(rows_per).enumerate() {
            let lo = blk * rows_per;
            s.spawn(move || {
                for (i, o) in chunk.iter_mut().enumerate() {
                    *o = dot(m.row(lo + i), v);
                }
            });
        }
    });
}

/// `out = M v` — parallel when the problem is big enough to pay for it
/// (and serial inside a class-level task — see the module docs).
pub fn gemv(m: &Matrix, v: &[f32], out: &mut [f32]) {
    gemv_threads(m, v, out, policy_threads(m.rows * m.cols));
}

// ---------------------------------------------------------------------------
// symmetric pairwise builds (gram, sqdist matrices)
// ---------------------------------------------------------------------------

/// Build the symmetric n×n matrix with `m[i][j] = f(i, j)` by evaluating
/// the upper triangle and mirroring.  Rows are handed out by an atomic
/// cursor (work stealing), which balances the shrinking triangle rows
/// without unsafe shared writes: workers buffer `(row, values)` locally
/// and the caller scatters after the join.  The buffering transiently
/// holds a second copy of the upper triangle (~n²/2 extra f32) — fine at
/// the per-class/chunk sizes this layer serves (n ≤ a few thousand);
/// ground sets much beyond that should go through the XLA `sqdist_chunk`
/// path instead.
pub fn symmetric_pairwise_threads(
    n: usize,
    threads: usize,
    f: impl Fn(usize, usize) -> f32 + Sync,
) -> Matrix {
    let mut m = Matrix::zeros(n, n);
    if n == 0 {
        return m;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for i in 0..n {
            for j in i..n {
                let v = f(i, j);
                m.data[i * n + j] = v;
                m.data[j * n + i] = v;
            }
        }
        return m;
    }
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, Vec<f32>)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut local: Vec<(usize, Vec<f32>)> = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let row: Vec<f32> = (i..n).map(|j| f(i, j)).collect();
                    local.push((i, row));
                }
                results.lock().unwrap().append(&mut local);
            });
        }
    });
    for (i, row) in results.into_inner().unwrap() {
        for (off, v) in row.into_iter().enumerate() {
            let j = i + off;
            m.data[i * n + j] = v;
            m.data[j * n + i] = v;
        }
    }
    m
}

fn symmetric_threads_for(n: usize, flops_per_entry: usize) -> usize {
    policy_threads(n * n / 2 * flops_per_entry.max(1))
}

/// Gram matrix `A Aᵀ` (parallel twin of [`crate::tensor::gram`]).
pub fn gram(a: &Matrix) -> Matrix {
    symmetric_pairwise_threads(a.rows, symmetric_threads_for(a.rows, a.cols), |i, j| {
        dot(a.row(i), a.row(j))
    })
}

/// Symmetric pairwise squared-distance matrix over the rows of `a` — the
/// CRAIG / facility-location similarity substrate.
pub fn pairwise_sqdist(a: &Matrix) -> Matrix {
    symmetric_pairwise_threads(a.rows, symmetric_threads_for(a.rows, a.cols), |i, j| {
        sqdist(a.row(i), a.row(j))
    })
}

// ---------------------------------------------------------------------------
// clamped column sums (facility-location initial gains)
// ---------------------------------------------------------------------------

/// `out[j] = Σ_i max(m[i][j], 0)` in f64 — exactly the facility-location
/// marginal gain of `j` under an empty selection, for every `j` at once.
/// Parallel over column blocks (each worker owns a disjoint slice of the
/// output and scans all rows for its columns).
pub fn colsum_pos_threads(m: &Matrix, threads: usize) -> Vec<f64> {
    colsum_impl(m, threads, true)
}

/// Policy-driven [`colsum_pos_threads`].
pub fn colsum_pos(m: &Matrix) -> Vec<f64> {
    colsum_pos_threads(m, policy_threads(m.rows * m.cols))
}

/// Plain (unclamped) f64 column sums `out[j] = Σ_i m[i][j]` — the
/// distance-backed facility-location heap seed, where clamping would
/// understate the gain upper bound on slightly-negative device-computed
/// squared distances.
pub fn colsum(m: &Matrix) -> Vec<f64> {
    colsum_impl(m, policy_threads(m.rows * m.cols), false)
}

fn colsum_impl(m: &Matrix, threads: usize, clamp_pos: bool) -> Vec<f64> {
    let (rows, cols) = (m.rows, m.cols);
    let mut out = vec![0.0f64; cols];
    if cols == 0 || rows == 0 {
        return out;
    }
    let threads = threads.clamp(1, cols);
    let cols_per = cols.div_ceil(threads);
    std::thread::scope(|s| {
        for (blk, chunk) in out.chunks_mut(cols_per).enumerate() {
            let lo = blk * cols_per;
            s.spawn(move || {
                for i in 0..rows {
                    let row = m.row(i);
                    for (off, acc) in chunk.iter_mut().enumerate() {
                        let v = row[lo + off];
                        if !clamp_pos || v > 0.0 {
                            *acc += v as f64;
                        }
                    }
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;
    use crate::testutil::forall;

    fn close(a: f32, b: f32, what: &str) {
        let tol = 1e-5 * (1.0 + b.abs());
        assert!((a - b).abs() <= tol, "{what}: {a} vs {b} (tol {tol})");
    }

    #[test]
    fn dot_matches_reference_across_shapes() {
        forall(40, |g| {
            let n = g.int(0, 257);
            let a = g.gauss_vec(n);
            let b = g.gauss_vec(n);
            close(dot(&a, &b), tensor::dot(&a, &b), "dot");
            close(norm2(&a), tensor::norm2(&a), "norm2");
        });
    }

    #[test]
    fn sqdist_matches_reference_across_shapes() {
        forall(40, |g| {
            let n = g.int(0, 203);
            let a = g.gauss_vec(n);
            let b = g.gauss_vec(n);
            close(sqdist(&a, &b), tensor::sqdist(&a, &b), "sqdist");
        });
    }

    #[test]
    fn gemv_parallel_matches_scalar_reference() {
        forall(25, |g| {
            let rows = g.int(1, 90);
            let cols = g.int(1, 40);
            let m = g.matrix(rows, cols);
            let v = g.gauss_vec(cols);
            let mut want = vec![0.0f32; rows];
            tensor::gemv(&m, &v, &mut want);
            // force the threaded path even on tiny shapes
            for threads in [1usize, 3, 8] {
                let mut got = vec![0.0f32; rows];
                gemv_threads(&m, &v, &mut got, threads);
                for r in 0..rows {
                    close(got[r], want[r], &format!("gemv t={threads} row {r}"));
                }
            }
        });
    }

    #[test]
    fn gemv_policy_entry_matches_reference_on_large_shape() {
        // big enough to cross PAR_MIN_FLOPS and exercise the real policy
        let mut rng = crate::rng::Rng::new(21);
        let m = Matrix::from_vec(700, 128, (0..700 * 128).map(|_| rng.gaussian_f32()).collect());
        let v: Vec<f32> = (0..128).map(|_| rng.gaussian_f32()).collect();
        let mut want = vec![0.0f32; 700];
        tensor::gemv(&m, &v, &mut want);
        let mut got = vec![0.0f32; 700];
        gemv(&m, &v, &mut got);
        for r in 0..700 {
            close(got[r], want[r], &format!("row {r}"));
        }
    }

    #[test]
    fn gram_matches_scalar_reference() {
        forall(20, |g| {
            let rows = g.int(1, 40);
            let cols = g.int(1, 24);
            let a = g.matrix(rows, cols);
            let want = tensor::gram(&a);
            for threads in [1usize, 4] {
                let got = symmetric_pairwise_threads(rows, threads, |i, j| dot(a.row(i), a.row(j)));
                for i in 0..rows {
                    for j in 0..rows {
                        close(got.at(i, j), want.at(i, j), &format!("gram t={threads} ({i},{j})"));
                    }
                }
            }
            let got = gram(&a);
            for i in 0..rows {
                close(got.at(i, i), want.at(i, i), "gram policy diag");
            }
        });
    }

    #[test]
    fn pairwise_sqdist_matches_scalar_reference() {
        forall(20, |g| {
            let rows = g.int(1, 35);
            let cols = g.int(1, 20);
            let a = g.matrix(rows, cols);
            for threads in [1usize, 5] {
                let got =
                    symmetric_pairwise_threads(rows, threads, |i, j| sqdist(a.row(i), a.row(j)));
                for i in 0..rows {
                    for j in 0..rows {
                        let want = tensor::sqdist(a.row(i), a.row(j));
                        close(got.at(i, j), want, &format!("sqdist t={threads} ({i},{j})"));
                    }
                    assert_eq!(got.at(i, i), 0.0);
                }
            }
        });
    }

    #[test]
    fn colsum_pos_matches_naive_clamped_sums() {
        forall(25, |g| {
            let rows = g.int(1, 40);
            let cols = g.int(1, 30);
            let m = g.matrix(rows, cols);
            for threads in [1usize, 4] {
                let got = colsum_pos_threads(&m, threads);
                for j in 0..cols {
                    let want: f64 =
                        (0..rows).map(|i| (m.at(i, j).max(0.0)) as f64).sum();
                    assert!(
                        (got[j] - want).abs() <= 1e-6 * (1.0 + want.abs()),
                        "col {j} t={threads}: {} vs {want}",
                        got[j]
                    );
                }
            }
            // the unclamped twin keeps negative entries (gaussian input
            // makes the two differ on almost every column)
            let plain = colsum(&m);
            for j in 0..cols {
                let want: f64 = (0..rows).map(|i| m.at(i, j) as f64).sum();
                assert!(
                    (plain[j] - want).abs() <= 1e-6 * (1.0 + want.abs()),
                    "colsum col {j}: {} vs {want}",
                    plain[j]
                );
            }
        });
    }

    #[test]
    fn empty_shapes_are_safe() {
        let m = Matrix::zeros(0, 5);
        let mut out = vec![];
        gemv(&m, &[0.0; 5], &mut out);
        assert!(symmetric_pairwise_threads(0, 4, |_, _| 0.0).data.is_empty());
        assert!(colsum_pos(&Matrix::zeros(0, 0)).is_empty());
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn num_threads_is_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn map_tasks_preserves_input_order() {
        let items: Vec<usize> = (0..57).collect();
        let want: Vec<usize> = items.iter().map(|&i| i * i + 1).collect();
        for threads in [1usize, 2, 4, 9] {
            let got = map_tasks_threads(&items, threads, |&i| i * i + 1);
            assert_eq!(got, want, "threads={threads}");
        }
        assert_eq!(map_tasks(&items, |&i| i * i + 1), want);
        let empty: Vec<usize> = Vec::new();
        assert!(map_tasks(&empty, |&i| i).is_empty());
    }

    #[test]
    fn task_workers_carry_the_depth_flag() {
        assert!(!in_task(), "test thread must start outside a task");
        let items: Vec<usize> = (0..16).collect();
        let flags = map_tasks_threads(&items, 4, |_| in_task());
        assert!(flags.iter().all(|&f| f), "every task must see in_task()");
        assert!(!in_task(), "flag must not leak back to the caller");
    }

    #[test]
    fn nested_fanout_degrades_to_serial_on_the_worker() {
        // inside a task, a nested map_tasks must run inline on the same
        // worker thread (no second level of spawns)
        let items: Vec<usize> = (0..8).collect();
        let ok = map_tasks_threads(&items, 4, |_| {
            let me = std::thread::current().id();
            let inner: Vec<usize> = (0..4).collect();
            let tids = map_tasks(&inner, |_| std::thread::current().id());
            tids.iter().all(|&t| t == me)
        });
        assert!(ok.iter().all(|&b| b));
    }

    #[test]
    fn inner_kernels_stay_correct_inside_tasks() {
        // policy kernels degrade to serial inside a task but must return
        // the same values
        let mut rng = crate::rng::Rng::new(33);
        let m = Matrix::from_vec(600, 128, (0..600 * 128).map(|_| rng.gaussian_f32()).collect());
        let v: Vec<f32> = (0..128).map(|_| rng.gaussian_f32()).collect();
        let mut want = vec![0.0f32; 600];
        gemv(&m, &v, &mut want);
        let items = [0usize, 1, 2];
        let got = map_tasks_threads(&items, 3, |_| {
            let mut out = vec![0.0f32; 600];
            gemv(&m, &v, &mut out);
            out
        });
        for g in got {
            assert_eq!(g, want);
        }
    }

    #[test]
    fn fanout_policy_never_trades_away_kernel_threading() {
        // a single task never fans out
        assert!(!fanout_wins(0, 0));
        assert!(!fanout_wins(1, 1 << 30));
        // tiny tasks (inner kernels serial either way) always fan out
        assert!(fanout_wins(2, PAR_MIN_FLOPS - 1));
        // big tasks fan out only when they can occupy the machine
        let t = num_threads();
        assert!(fanout_wins(t.max(2), 1 << 30));
        if t > 2 {
            assert!(!fanout_wins(2, 1 << 30));
        }
    }

    #[test]
    fn for_chunks_covers_every_element_once() {
        for threads in [1usize, 2, 5] {
            let mut out = vec![0u32; 37];
            for_chunks_threads(&mut out, threads, |lo, chunk| {
                for (off, v) in chunk.iter_mut().enumerate() {
                    *v += (lo + off) as u32 + 1;
                }
            });
            let want: Vec<u32> = (1..=37).collect();
            assert_eq!(out, want, "threads={threads}");
        }
        let mut empty: Vec<u32> = Vec::new();
        for_chunks(&mut empty, 1 << 20, |_, _| panic!("no chunks on empty input"));
    }
}
