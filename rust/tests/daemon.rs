//! Selection-daemon contracts over real unix sockets.
//!
//! Each test runs its own daemon on an ephemeral socket (no shared state,
//! no port collisions) and drives it with real clients:
//!
//! - round-trip: ping → select → report, and the same request twice selects
//!   identically (the engine-pool reset contract, observed from outside)
//! - backpressure: a full queue sheds with a typed `overloaded` response
//!   *promptly* — never a hang, never an unbounded queue
//! - deadlines: a round that cannot meet its deadline yields a typed
//!   `deadline_exceeded`, and the daemon survives to serve the next request
//! - isolation: the jsonlite hostile corpus plus a mid-round disconnector,
//!   concurrent with a well-formed client whose rounds must all succeed
//! - graceful drain: a `shutdown` with rounds in flight completes every
//!   admitted round before `serve` returns its final stats
//! - fault plumbing: a `--fault-plan`-style outage degrades (ladder) but
//!   still serves, and the per-rung counts surface in `stats`

use std::time::{Duration, Instant};

use gradmatch::engine::{SelectionRequest, SketchPlan};
use gradmatch::fault::FaultPlan;
use gradmatch::jsonlite::{hostile_corpus, Json};
use gradmatch::server::{
    ephemeral_socket_path, serve, Bind, DaemonClient, DaemonStats, SelectSpec, ServeOpts,
};

// -- harness ----------------------------------------------------------------

fn small_request(rng_tag: u64) -> SelectionRequest {
    SelectionRequest {
        strategy: "gradmatch".to_string(),
        budget: 16,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag,
        ground: (0..128).collect(),
        shards: None,
        sketch: None,
    }
}

fn small_spec(run_id: &str, rng_tag: u64) -> SelectSpec {
    let mut spec = SelectSpec::new(run_id, small_request(rng_tag));
    spec.n_train = 128;
    spec.chunk = 32;
    spec.h = 4;
    spec
}

/// Start a daemon on an ephemeral unix socket; returns the join handle
/// (yields the drain snapshot) and the bind address for clients.
fn start(tag: &str, mut opts_fn: impl FnMut(&mut ServeOpts)) -> (std::thread::JoinHandle<anyhow::Result<DaemonStats>>, Bind) {
    let bind = Bind::Unix(ephemeral_socket_path(tag));
    let mut opts = ServeOpts::new(bind.clone());
    opts_fn(&mut opts);
    let handle = std::thread::spawn(move || serve(opts));
    (handle, bind)
}

fn connect(bind: &Bind) -> DaemonClient {
    DaemonClient::connect_retry(bind, Duration::from_secs(10)).expect("daemon did not come up")
}

fn resp_type(j: &Json) -> &str {
    j.get("type").and_then(Json::as_str).unwrap_or("<none>")
}

fn err_code(j: &Json) -> &str {
    j.get("code").and_then(Json::as_str).unwrap_or("<none>")
}

/// A fault plan whose only effect is a latency spike on every dispatch —
/// the deterministic way to make rounds slow enough to stack up.
fn slow_plan(spike_ms: u64) -> FaultPlan {
    let mut plan = FaultPlan::none(7);
    plan.spike_every = 1;
    plan.spike_ms = spike_ms;
    plan
}

// -- contracts --------------------------------------------------------------

#[test]
fn round_trip_determinism_and_stats_over_a_unix_socket() {
    let (daemon, bind) = start("roundtrip", |_| {});
    let mut client = connect(&bind);

    let pong = client.ping().unwrap();
    assert_eq!(resp_type(&pong), "pong");

    let spec = small_spec("tenant-a", 1000);
    let first = client.select(&spec).unwrap();
    assert_eq!(resp_type(&first), "report", "got: {}", first.dump());
    let indices = |r: &Json| r.path(&["report", "selection", "indices"]).map(Json::dump);
    assert_eq!(
        first
            .path(&["report", "selection", "indices"])
            .and_then(Json::as_arr)
            .map(Vec::len),
        Some(16),
        "budget must be honored"
    );

    // the same request again must select identically — the pool resets the
    // engine round and the request's (seed, rng_tag) pins all randomness
    let second = client.select(&spec).unwrap();
    assert_eq!(resp_type(&second), "report");
    assert_eq!(indices(&first), indices(&second));

    // a different rng_tag is a different round
    let mut other = spec.clone();
    other.request.rng_tag = 2000;
    let third = client.select(&other).unwrap();
    assert_eq!(resp_type(&third), "report");

    let stats = client.stats().unwrap();
    assert_eq!(resp_type(&stats), "stats");
    assert_eq!(stats.get("rounds_served").and_then(Json::as_usize), Some(3));
    assert_eq!(stats.get("queue_depth").and_then(Json::as_usize), Some(0));
    assert_eq!(stats.get("inflight_rounds").and_then(Json::as_usize), Some(0));
    assert_eq!(
        stats.path(&["degradation", "none"]).and_then(Json::as_usize),
        Some(3),
        "healthy rounds land on the 'none' rung: {}",
        stats.dump()
    );
    assert_eq!(stats.get("engines_built").and_then(Json::as_usize), Some(1), "one tenant, one engine");

    let ok = client.shutdown().unwrap();
    assert_eq!(resp_type(&ok), "ok");
    let snap = daemon.join().unwrap().unwrap();
    assert_eq!(snap.rounds_served, 3);
    assert_eq!(snap.queue_depth, 0);
    assert!(snap.draining);
}

#[test]
fn full_queue_sheds_with_typed_overloaded_not_a_hang() {
    // every dispatch sleeps 150ms → rounds are slow; cap the queue at 2 so
    // a burst of 8 must shed most of itself
    let (daemon, bind) = start("overload", |o| {
        o.fault_plan = Some(slow_plan(150));
        o.queue_cap = 2;
    });
    // make sure the daemon is up before the burst
    connect(&bind).ping().unwrap();

    let mut workers = Vec::new();
    for i in 0..8 {
        let bind = bind.clone();
        workers.push(std::thread::spawn(move || {
            let mut client = connect(&bind);
            // one shared run id: the admitted rounds serialize, keeping the
            // queue occupied while the shed responses come back
            let spec = small_spec("hot-tenant", 1000 + i);
            let t0 = Instant::now();
            let resp = client.select(&spec).unwrap();
            (resp, t0.elapsed())
        }));
    }
    let results: Vec<(Json, Duration)> = workers.into_iter().map(|w| w.join().unwrap()).collect();

    let mut reports = 0usize;
    let mut shed = 0usize;
    for (resp, elapsed) in &results {
        match resp_type(resp) {
            "report" => reports += 1,
            "error" => {
                assert_eq!(err_code(resp), "overloaded", "got: {}", resp.dump());
                shed += 1;
                assert!(
                    *elapsed < Duration::from_secs(5),
                    "shedding must be prompt, took {elapsed:?}"
                );
            }
            other => panic!("unexpected response type '{other}': {}", resp.dump()),
        }
    }
    assert_eq!(reports + shed, 8);
    assert!(reports >= 1, "the admitted rounds must be served");
    assert!(shed >= 1, "an 8-burst against queue_cap=2 must shed");

    connect(&bind).shutdown().unwrap();
    let snap = daemon.join().unwrap().unwrap();
    assert_eq!(snap.rounds_served as usize, reports);
    assert_eq!(snap.shed_overloaded as usize, shed);
}

#[test]
fn impossible_deadline_is_a_typed_deadline_exceeded() {
    // the first dispatch alone sleeps 300ms — a 50ms deadline cannot be met
    let (daemon, bind) = start("deadline", |o| {
        o.fault_plan = Some(slow_plan(300));
    });
    let mut client = connect(&bind);

    let mut spec = small_spec("deadline-tenant", 1000);
    spec.deadline_ms = Some(50);
    let t0 = Instant::now();
    let resp = client.select(&spec).unwrap();
    assert_eq!(resp_type(&resp), "error", "got: {}", resp.dump());
    assert_eq!(err_code(&resp), "deadline_exceeded");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "deadline reply must not wait for the slow round"
    );

    // the daemon survives and the connection is still usable: a round with
    // a generous deadline succeeds
    let mut ok_spec = small_spec("deadline-tenant", 2000);
    ok_spec.deadline_ms = Some(30_000);
    let resp = client.select(&ok_spec).unwrap();
    assert_eq!(resp_type(&resp), "report", "got: {}", resp.dump());

    client.shutdown().unwrap();
    let snap = daemon.join().unwrap().unwrap();
    assert!(
        snap.deadline_replies + snap.deadline_skipped >= 1,
        "the miss must be counted: {snap:?}"
    );
    assert!(snap.rounds_served >= 1);
}

#[test]
fn hostile_and_disconnecting_clients_do_not_poison_a_well_formed_one() {
    let (daemon, bind) = start("isolation", |_| {});
    connect(&bind).ping().unwrap();

    // adversary 1: the full jsonlite hostile corpus down one connection —
    // every non-blank line must come back as a typed error, never hang or
    // kill the daemon
    let hostile_bind = bind.clone();
    let hostile = std::thread::spawn(move || {
        let mut client = connect(&hostile_bind);
        let mut rejected = 0usize;
        for line in hostile_corpus() {
            if line.trim().is_empty() {
                continue; // blank lines are skipped by the protocol, no reply
            }
            client.send_raw(&line).expect("send");
            let resp = client.recv().expect("a malformed line still gets a reply");
            assert_eq!(resp_type(&resp), "error", "line {line:?} got: {}", resp.dump());
            rejected += 1;
        }
        rejected
    });

    // adversary 2: submits a real round, then vanishes mid-round
    let vanish_bind = bind.clone();
    let vanisher = std::thread::spawn(move || {
        let mut client = connect(&vanish_bind);
        client.send(&small_spec("vanisher", 1).to_json()).unwrap();
        // drop without reading the reply — the daemon must shrug
    });

    // the well-formed client: every round must succeed throughout
    let mut client = connect(&bind);
    for tag in 0..5 {
        let resp = client.select(&small_spec("good-tenant", 3000 + tag)).unwrap();
        assert_eq!(resp_type(&resp), "report", "round {tag} got: {}", resp.dump());
    }

    let rejected = hostile.join().unwrap();
    assert!(rejected > 20, "the corpus should exercise many rejects, got {rejected}");
    vanisher.join().unwrap();

    // after all that abuse the daemon still answers
    let stats = connect(&bind).stats().unwrap();
    assert!(stats.get("rounds_served").and_then(Json::as_usize).unwrap() >= 5);
    assert!(stats.get("bad_requests").and_then(Json::as_usize).unwrap() >= rejected);

    connect(&bind).shutdown().unwrap();
    let snap = daemon.join().unwrap().unwrap();
    assert!(snap.rounds_served >= 5);
}

#[test]
fn oversized_request_is_rejected_and_only_that_connection_closed() {
    let (daemon, bind) = start("oversized", |o| {
        o.max_request_bytes = 1024;
    });
    let mut fat = connect(&bind);
    let padding = "x".repeat(4096);
    fat.send_raw(&format!("{{\"type\":\"ping\",\"pad\":\"{padding}\"}}")).unwrap();
    let resp = fat.recv().unwrap();
    assert_eq!(resp_type(&resp), "error");
    assert_eq!(err_code(&resp), "oversized", "got: {}", resp.dump());
    // the oversized connection is closed...
    assert!(fat.ping().is_err(), "oversized connection must be dropped");
    // ...but a fresh one works fine
    let mut client = connect(&bind);
    assert_eq!(resp_type(&client.ping().unwrap()), "pong");

    client.shutdown().unwrap();
    let snap = daemon.join().unwrap().unwrap();
    assert!(snap.oversized >= 1);
}

#[test]
fn graceful_drain_completes_every_admitted_round() {
    let (daemon, bind) = start("drain", |o| {
        o.fault_plan = Some(slow_plan(150));
    });
    connect(&bind).ping().unwrap();

    // three tenants submit slow rounds
    let mut workers = Vec::new();
    for (i, run) in ["drain-a", "drain-b", "drain-c"].iter().enumerate() {
        let bind = bind.clone();
        let run = run.to_string();
        workers.push(std::thread::spawn(move || {
            let mut client = connect(&bind);
            client.select(&small_spec(&run, 100 + i as u64)).unwrap()
        }));
    }

    // wait until all three are admitted (queued or in flight), then pull
    // the plug
    let mut observer = connect(&bind);
    let t0 = Instant::now();
    loop {
        let stats = observer.stats().unwrap();
        let pending = stats.get("queue_depth").and_then(Json::as_usize).unwrap_or(0)
            + stats.get("inflight_rounds").and_then(Json::as_usize).unwrap_or(0);
        if pending >= 3 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(20), "rounds never became pending");
        std::thread::sleep(Duration::from_millis(10));
    }
    let ok = observer.shutdown().unwrap();
    assert_eq!(resp_type(&ok), "ok");

    // every admitted round completes with a real report — a drain finishes
    // work, it does not drop it
    for w in workers {
        let resp = w.join().unwrap();
        assert_eq!(resp_type(&resp), "report", "got: {}", resp.dump());
    }

    let snap = daemon.join().unwrap().unwrap();
    assert_eq!(snap.rounds_served, 3);
    assert_eq!(snap.queue_depth, 0, "nothing may be left behind");
    assert!(snap.draining);

    // after the drain, the socket is gone: new selects are refused at
    // connect time, not silently queued
    assert!(DaemonClient::connect(&bind).is_err());
}

#[test]
fn sketch_plan_round_trips_and_lenient_wire_stays_compatible() {
    // mirrors PR 8's ShardPlan wire pinning for the sketch fields: new
    // clients round-trip the plan and the probe counters; old clients
    // (no 'sketch' key), width-only plans, unknown fields, and explicit
    // nulls all parse leniently and get served
    let (daemon, bind) = start("sketchwire", |_| {});
    let mut client = connect(&bind);

    // a new client's sketched round: 'gradmatch' stages h+1 = 5 columns
    // per class, so width 3 applies, and the probe fields come back
    let mut spec = small_spec("sketch-tenant", 1000);
    spec.request.sketch = Some(SketchPlan { width: 3, refit: true, seed_salt: 5 });
    let resp = client.select(&spec).unwrap();
    assert_eq!(resp_type(&resp), "report", "got: {}", resp.dump());
    assert_eq!(
        resp.path(&["report", "round", "sketch_width"]).and_then(Json::as_usize),
        Some(3),
        "the applied sketch width must survive the wire: {}",
        resp.dump()
    );
    for key in ["sketch_secs", "refit_secs"] {
        let secs = resp.path(&["report", "round", key]).and_then(Json::as_f64);
        assert!(
            secs.is_some_and(|v| v >= 0.0),
            "round probe must carry '{key}': {}",
            resp.dump()
        );
    }

    // an old client omitting the key entirely: served, unsketched
    let legacy = small_spec("legacy-tenant", 2000).to_json().dump();
    assert!(!legacy.contains("sketch"), "a None plan must be omitted on the wire: {legacy}");
    client.send_raw(&legacy).unwrap();
    let resp = client.recv().unwrap();
    assert_eq!(resp_type(&resp), "report", "got: {}", resp.dump());
    assert_eq!(
        resp.path(&["report", "round", "sketch_width"]).and_then(Json::as_usize),
        Some(0),
        "legacy requests stay flat: {}",
        resp.dump()
    );

    // a hand-written width-only plan with unknown inner AND outer fields:
    // lenient parse (refit defaults on, salt 0, unknowns ignored), round
    // still sketches
    let base = small_spec("fwd-tenant", 3000).to_json().dump();
    let doctored = base.replacen(
        "\"request\":{",
        "\"request\":{\"sketch\":{\"width\":3,\"future_knob\":true},\"future_field\":\"x\",",
        1,
    );
    assert_ne!(doctored, base, "doctoring must hit the request object");
    client.send_raw(&doctored).unwrap();
    let resp = client.recv().unwrap();
    assert_eq!(resp_type(&resp), "report", "unknown fields must be tolerated: {}", resp.dump());
    assert_eq!(
        resp.path(&["report", "round", "sketch_width"]).and_then(Json::as_usize),
        Some(3),
        "a width-only plan must sketch with default refit/salt: {}",
        resp.dump()
    );

    // an explicit null plan is the flat path
    let base = small_spec("null-tenant", 4000).to_json().dump();
    let doctored = base.replacen("\"request\":{", "\"request\":{\"sketch\":null,", 1);
    assert_ne!(doctored, base);
    client.send_raw(&doctored).unwrap();
    let resp = client.recv().unwrap();
    assert_eq!(resp_type(&resp), "report", "got: {}", resp.dump());
    assert_eq!(
        resp.path(&["report", "round", "sketch_width"]).and_then(Json::as_usize),
        Some(0)
    );

    client.shutdown().unwrap();
    let snap = daemon.join().unwrap().unwrap();
    assert_eq!(snap.rounds_served, 4);
}

#[test]
fn hard_outage_degrades_through_the_ladder_but_still_serves() {
    // fail_from=1: every oracle dispatch fails — the engine must walk the
    // degradation ladder (random fallback on a fresh engine) and the rung
    // must surface in the daemon's stats
    let (daemon, bind) = start("outage", |o| {
        let mut plan = FaultPlan::none(11);
        plan.fail_from = 1;
        o.fault_plan = Some(plan);
    });
    let mut client = connect(&bind);
    let resp = client.select(&small_spec("outage-tenant", 500)).unwrap();
    assert_eq!(resp_type(&resp), "report", "degraded is still served: {}", resp.dump());
    assert_eq!(
        resp.path(&["report", "round", "degradation"]).and_then(Json::as_str),
        Some("random-fallback")
    );
    let stats = client.stats().unwrap();
    assert_eq!(
        stats.path(&["degradation", "random-fallback"]).and_then(Json::as_usize),
        Some(1),
        "per-rung counts must surface: {}",
        stats.dump()
    );

    client.shutdown().unwrap();
    let snap = daemon.join().unwrap().unwrap();
    assert_eq!(snap.degradation[2], 1);
}
