//! Table 10: redundant points — the fraction of training data never used
//! across all selection rounds of a run.  Paper shape: large at small
//! budgets (~90% at 1%), shrinking with budget; adaptive strategies keep
//! re-selecting overlapping informative cores.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new(&bh::artifacts_dir())?;
    let strategies = ["craig-pb", "glister", "gradmatch", "gradmatch-pb"];
    let budgets = [0.01, 0.05, 0.10, 0.30];

    bh::section("Table 10 — % of training points never selected (synmnist)");
    let mut header = vec!["strategy".to_string()];
    header.extend(budgets.iter().map(|b| format!("{:.0}%", b * 100.0)));
    bh::table_header(&header.iter().map(String::as_str).collect::<Vec<_>>());

    let mut at_1 = Vec::new(); // (strategy, redundant_frac)
    let mut gm = std::collections::HashMap::new();
    for strat in strategies {
        let mut row = vec![strat.to_string()];
        for &b in &budgets {
            let mut cfg = bh::bench_config("synmnist", "lenet_s");
            cfg.strategy = strat.into();
            cfg.budget_frac = b;
            cfg.epochs = 12;
            cfg.r_interval = 3; // several selection rounds
            let r = coord.run_one(&cfg, cfg.seed)?;
            row.push(format!("{:.2}", r.redundant_frac * 100.0));
            if (b - 0.01).abs() < 1e-9 {
                at_1.push((strat, r.redundant_frac));
            }
            if strat == "gradmatch" {
                gm.insert((b * 100.0) as usize, r.redundant_frac);
            }
        }
        bh::table_row(&row);
    }

    let mut ok = true;
    // PB variants quantize to whole 128-row mini-batches, so at n=1500 a
    // "1%" budget still touches a full batch per round — only per-sample
    // strategies see the paper's ~90% redundancy at 1%
    ok &= bh::shape_check(
        "table10: ~85%+ redundant at 1% for per-sample strategies",
        at_1.iter()
            .filter(|(s, _)| !s.ends_with("-pb"))
            .all(|&(_, f)| f > 0.85),
    );
    ok &= bh::shape_check(
        "table10: redundancy shrinks as budget grows (gradmatch)",
        gm[&30] < gm[&1],
    );
    println!("\ntable10_redundant: {}", if ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
