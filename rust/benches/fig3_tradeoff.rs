//! Figure 3 (a–e) + Figure 1: speedup vs relative-error trade-off scatter
//! per dataset, miniature regeneration.  Prints one scatter row per
//! (strategy, budget) — smaller subsets left, larger right — and the Fig.-1
//! efficiency summary, then shape-checks the paper's qualitative claims:
//! GRAD-MATCH variants sit toward the bottom-right (better trade-off) of
//! RANDOM and the other baselines.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let datasets = [("synmnist", "lenet_s"), ("syncifar100", "resnet_s")];
    let strategies = [
        "random",
        "glister",
        "craig",
        "craig-pb",
        "gradmatch",
        "gradmatch-pb",
        "gradmatch-pb-warm",
    ];
    let budgets = [0.05, 0.10, 0.30];

    let mut coord = Coordinator::new(&bh::artifacts_dir())?;
    let mut all_ok = true;

    for (ds, model) in datasets {
        bh::section(&format!("Fig. 3 trade-off — {ds} ({model})"));
        let mut cfg = bh::bench_config(ds, model);
        cfg.epochs = 12;
        cfg.r_interval = 4;
        let (rows, secs) = bh::timed(|| coord.sweep(&cfg, &strategies, &budgets));
        let rows = rows?;
        println!("(sweep wall time {secs:.1}s; full skyline acc {:.2}%)", rows[0].full_acc * 100.0);
        bh::table_header(&["strategy", "budget%", "acc%", "rel-err%", "speedup", "energy-x"]);
        for r in &rows {
            bh::table_row(&[
                r.summary.strategy.clone(),
                format!("{:.0}", r.summary.budget_frac * 100.0),
                format!("{:.2}", r.acc_mean * 100.0),
                format!("{:.2}", r.rel_err_pct),
                format!("{:.2}", r.speedup),
                format!("{:.2}", r.energy_ratio),
            ]);
        }

        // Fig. 1 summary for the flagship variant
        println!("\nFig.-1 efficiency block (gradmatch-pb-warm):");
        for r in rows.iter().filter(|r| r.summary.strategy == "gradmatch-pb-warm") {
            println!(
                "  {:>3.0}% subset: {:.2}x speedup, {:.2}% accuracy drop",
                r.summary.budget_frac * 100.0,
                r.speedup,
                r.rel_err_pct
            );
        }

        // paper-shape checks
        let get = |strat: &str, b: f64| {
            rows.iter()
                .find(|r| r.summary.strategy == strat && (r.summary.budget_frac - b).abs() < 1e-9)
                .unwrap()
        };
        for &b in &budgets {
            let rnd = get("random", b);
            let best_gm = ["gradmatch", "gradmatch-pb", "gradmatch-pb-warm"]
                .iter()
                .map(|s| get(s, b).acc_mean)
                .fold(0.0f64, f64::max);
            all_ok &= bh::shape_check(
                &format!("{ds}: best GRAD-MATCH beats RANDOM at {:.0}%", b * 100.0),
                best_gm >= rnd.acc_mean,
            );
        }
        // at miniature scale the wall-clock claims only hold where the
        // selection cost is amortized (cheap lenet_s selection); the full
        // claims are exercised at scale by examples/e2e_driver
        if ds == "synmnist" {
            let gm30 = get("gradmatch-pb-warm", 0.30);
            all_ok &= bh::shape_check(
                &format!("{ds}: 30% gradmatch-pb-warm within 8pp of full"),
                gm30.rel_err_pct < 8.0,
            );
            all_ok &= bh::shape_check(
                &format!("{ds}: 30% gradmatch-pb-warm speedup > 1x"),
                gm30.speedup > 1.0,
            );
        } else {
            let gm30 = get("gradmatch-pb-warm", 0.30);
            let rnd30 = get("random", 0.30);
            all_ok &= bh::shape_check(
                &format!("{ds}: 30% gradmatch-pb-warm rel-err well below random"),
                gm30.rel_err_pct < rnd30.rel_err_pct + 1.0,
            );
        }
    }

    println!("\nfig3_tradeoff: {}", if all_ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
