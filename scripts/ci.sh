#!/usr/bin/env bash
# CI gate for the workspace: build, tests, formatting, lints.
#
#   scripts/ci.sh          # everything
#   scripts/ci.sh --fast   # build + tests only (skip fmt/clippy)
#
# Tier-1 (enforced): cargo build --release && cargo test -q.
# fmt/clippy run when the components are installed; a missing component
# is reported but does not fail the gate (offline toolchains may omit
# them), while an installed component failing DOES fail.

set -euo pipefail
cd "$(dirname "$0")/.."

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "$fast" == "1" ]]; then
    echo "ci: fast mode — skipped fmt/clippy"
    exit 0
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "ci: rustfmt not installed — skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -- -D warnings =="
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "ci: clippy not installed — skipping lints"
fi

echo "ci: OK"
