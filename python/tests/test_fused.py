"""Fused-state train step: pack/unpack correctness and equivalence with the
unfused step (the Rust trainer's hot path depends on both)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M

SPEC = M.ModelSpec("tiny", d=12, h=8, c=4, batch=16, chunk=16)


@pytest.fixture(scope="module")
def params():
    return M.init(SPEC, jnp.int32(3))


def test_state_size_formula():
    want = 2 * (12 * 8 + 8 + 8 * 4 + 4)
    assert M.state_size(SPEC) == want


def test_pack_unpack_roundtrip(params):
    momenta = tuple(jnp.full_like(p, 0.25) for p in params)
    flat = M.pack_state(params, momenta)
    assert flat.shape == (M.state_size(SPEC),)
    p2, m2 = M.unpack_state(SPEC, flat)
    for a, b in zip(params, p2):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(momenta, m2):
        np.testing.assert_array_equal(a, b)


def test_pack_layout_params_then_momenta(params):
    momenta = tuple(jnp.zeros_like(p) for p in params)
    flat = np.asarray(M.pack_state(params, momenta))
    n_params = sum(int(np.prod(p.shape)) for p in params)
    np.testing.assert_array_equal(flat[n_params:], 0.0)
    np.testing.assert_allclose(flat[: 12 * 8], np.asarray(params[0]).ravel())


def test_fused_step_equals_unfused(params):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 12)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 4, size=16).astype(np.int32))
    w = jnp.ones((16,), jnp.float32)
    momenta = tuple(jnp.full_like(p, 0.01) for p in params)
    lr = jnp.float32(0.1)

    out = M.train_step(SPEC, params, momenta, x, y, w, lr)
    state = M.pack_state(params, momenta)
    new_state, loss_f, correct_f = M.train_step_fused(SPEC, state, x, y, w, lr)

    np.testing.assert_allclose(float(out[8]), float(loss_f), rtol=1e-6)
    np.testing.assert_allclose(float(out[9]), float(correct_f), rtol=1e-6)
    want = M.pack_state(tuple(out[:4]), tuple(out[4:8]))
    np.testing.assert_allclose(np.asarray(new_state), np.asarray(want), rtol=1e-6, atol=1e-7)


def test_fused_chain_multiple_steps(params):
    """Threading the packed state through steps == stepping unfused."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(16, 12)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 4, size=16).astype(np.int32))
    w = jnp.ones((16,), jnp.float32)
    lr = jnp.float32(0.05)

    p, m = params, tuple(jnp.zeros_like(v) for v in params)
    state = M.pack_state(p, m)
    for _ in range(3):
        out = M.train_step(SPEC, p, m, x, y, w, lr)
        p, m = tuple(out[:4]), tuple(out[4:8])
        state, _, _ = M.train_step_fused(SPEC, state, x, y, w, lr)
    p2, m2 = M.unpack_state(SPEC, state)
    for a, b in zip(p, p2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    for a, b in zip(m, m2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
